"""Unit + property tests for the paper's core: schedule, samplers, grouping,
Eq. 3 loss, Alg. 1 shared sampling, Alg. 2 training, LoRA."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.config import OptimConfig, SageConfig, get_config
from repro.core import grouping, lora as lora_lib, samplers, trainer
from repro.core import sage_loss as losses
from repro.core.schedule import Schedule, ddim_timesteps, make_schedule
from repro.core.shared_sampling import (group_mean, independent_sample,
                                        shared_sample)
from repro.models import dit

SCHED = make_schedule(1000)
CFG = get_config("sage-dit", smoke=True)
SAGE = SageConfig(total_steps=8, share_ratio=0.25, guidance_scale=3.0)


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

def test_schedule_vp_invariant():
    a, s = np.asarray(SCHED.alphas), np.asarray(SCHED.sigmas)
    np.testing.assert_allclose(a ** 2 + s ** 2, 1.0, atol=1e-5)
    assert a[0] == pytest.approx(1.0, abs=1e-4)
    assert np.all(np.diff(a) <= 1e-7)           # alpha monotone decreasing


@given(st.integers(2, 100))
@settings(max_examples=20, deadline=None)
def test_ddim_grid(n):
    ts = ddim_timesteps(1000, n)
    assert len(ts) == n + 1
    assert ts[0] == 1000 and ts[-1] == 0
    assert np.all(np.diff(ts) < 0)


def test_ddim_step_identity_at_same_t():
    z = jnp.ones((2, 4, 4, 3))
    eps = jnp.zeros_like(z)
    out = samplers.ddim_step(SCHED, z, jnp.int32(500), jnp.int32(500), eps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(z), rtol=1e-5)


def test_ddim_recovers_z0_with_true_eps():
    """One giant DDIM step with the exact eps recovers z0 exactly."""
    key = jax.random.PRNGKey(0)
    z0 = jax.random.normal(key, (2, 4, 4, 3))
    eps = jax.random.normal(jax.random.fold_in(key, 1), z0.shape)
    t = jnp.int32(700)
    zt = SCHED.alpha(t) * z0 + SCHED.sigma(t) * eps
    out = samplers.ddim_step(SCHED, zt, t, jnp.int32(0), eps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(z0), atol=1e-4)


# ---------------------------------------------------------------------------
# grouping
# ---------------------------------------------------------------------------

@given(st.integers(2, 40), st.floats(0.1, 0.9))
@settings(max_examples=25, deadline=None)
def test_grouping_invariants(m, tau):
    rng = np.random.RandomState(m)
    e = rng.randn(m, 16)
    sim = grouping.similarity_matrix(e)
    groups = grouping.greedy_clique_groups(sim, tau, group_max=5)
    flat = [i for g in groups for i in g]
    assert sorted(flat) == list(range(m))            # partition: cover, no dup
    for g in groups:
        assert 1 <= len(g) <= 5
        for i in g:
            for j in g:
                if i != j:
                    assert sim[i, j] > tau           # pairwise clique property


def test_pad_groups_mask():
    idx, mask = grouping.pad_groups([[0, 1, 2], [3], [4, 5, 6, 7, 8, 9, 10]],
                                    group_size=5)
    assert idx.shape == mask.shape
    assert mask.sum() == 11
    # oversize group split
    assert idx.shape[0] == 4


def test_cost_saving_matches_paper_form():
    # beta = 40% of 30 steps, groups of ~2.5 -> paper reports 25.5%
    groups = [[0, 1, 2], [3, 4], [5, 6, 7], [8, 9]]   # M=10, K=4
    out = grouping.cost_saving(groups, total_steps=30, branch_point=18)
    expect = 1.0 - (4 * 12 * 2 + 10 * 18 * 2) / (10 * 30 * 2)
    assert out["saving"] == pytest.approx(expect)
    # shared-uncond CFG strictly increases saving
    out2 = grouping.cost_saving(groups, 30, 18, shared_uncond=True)
    assert out2["saving"] > out["saving"]


# ---------------------------------------------------------------------------
# Eq. 3 loss + Alg. 2 step
# ---------------------------------------------------------------------------

def _toy_batch(key, K=2, N=3):
    kz, kc = jax.random.split(key)
    H = CFG.latent_size
    z = jax.random.normal(kz, (K, N, H, H, CFG.latent_channels))
    cond = jax.random.normal(kc, (K, N, CFG.cond_len, CFG.cond_dim))
    mask = jnp.ones((K, N))
    return {"z": z, "cond": cond, "mask": mask}


def test_group_mean_masked():
    x = jnp.stack([jnp.stack([jnp.ones(4), 3 * jnp.ones(4), 99 * jnp.ones(4)])])
    mask = jnp.array([[1.0, 1.0, 0.0]])
    np.testing.assert_allclose(np.asarray(group_mean(x, mask)[0]),
                               2 * np.ones(4), rtol=1e-6)


def test_sage_loss_finite_and_parts():
    params = dit.init_params(CFG, jax.random.PRNGKey(0))
    batch = _toy_batch(jax.random.PRNGKey(1))
    eps_fn = lambda z, t, c: dit.forward(params, CFG, z, t, c)
    loss, parts = losses.sage_loss(eps_fn, SCHED, SAGE, jax.random.PRNGKey(2),
                                   batch["z"], batch["cond"], batch["mask"])
    assert np.isfinite(float(loss))
    assert set(parts) == {"shared", "soft", "branch"}
    # with an untrained (zero-output) DiT, eps_pred ~ 0 -> branch ~ E||e||^2 ~ 1
    assert 0.0 < float(parts["branch"]) < 5.0


def test_sage_train_step_descends():
    opt = OptimConfig(lr=2e-3)
    state = trainer.init_state(CFG, opt, jax.random.PRNGKey(0))
    step = trainer.make_sage_train_step(CFG, SAGE, SCHED, opt)
    batch = _toy_batch(jax.random.PRNGKey(1))
    losses_seen = []
    for i in range(8):
        state, m = step(state, batch, jax.random.PRNGKey(i + 10))
        losses_seen.append(float(m["loss"]))
    assert losses_seen[-1] < losses_seen[0]          # same batch -> must descend


def test_lora_only_updates_lora():
    opt = OptimConfig(lr=1e-3)
    state = trainer.init_state(CFG, opt, jax.random.PRNGKey(0), lora_rank=4)
    step = trainer.make_sage_train_step(CFG, SAGE, SCHED, opt, lora_rank=4)
    batch = _toy_batch(jax.random.PRNGKey(1))
    before = jax.tree.map(lambda x: x.copy(), state["params"])
    state, m = step(state, batch, jax.random.PRNGKey(3))
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert any(float(jnp.abs(x).sum()) > 0
               for ab in state["lora"].values() for x in [ab["b"]])


def test_lora_merge_zero_b_is_identity():
    params = dit.init_params(CFG, jax.random.PRNGKey(0))
    lo = lora_lib.init_lora(params, 4, jax.random.PRNGKey(1))
    merged = lora_lib.merge(params, lo)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


# ---------------------------------------------------------------------------
# Alg. 1 shared sampling
# ---------------------------------------------------------------------------

def test_shared_sampling_shapes_and_nfe():
    params = dit.init_params(CFG, jax.random.PRNGKey(0))
    eps_fn = lambda z, t, c: dit.forward(params, CFG, z, t, c)
    K, N = 2, 3
    cond = jax.random.normal(jax.random.PRNGKey(1),
                             (K, N, CFG.cond_len, CFG.cond_dim))
    mask = jnp.ones((K, N))
    null = jnp.zeros((CFG.cond_len, CFG.cond_dim))
    H = CFG.latent_size
    out = shared_sample(eps_fn, SCHED, SAGE, jax.random.PRNGKey(2), cond,
                        mask, null, (H, H, CFG.latent_channels))
    assert out["latents"].shape == (K, N, H, H, CFG.latent_channels)
    assert bool(jnp.all(jnp.isfinite(out["latents"])))
    T, Ts = SAGE.total_steps, SAGE.branch_point
    assert int(out["nfe"]) == 2 * K * (T - Ts) + 2 * K * N * Ts


def test_shared_equals_independent_at_zero_sharing():
    """beta=0 with identical per-member noise must reduce to independent
    sampling of each member (the scheme is a strict generalisation)."""
    params = dit.init_params(CFG, jax.random.PRNGKey(0))
    eps_fn = lambda z, t, c: dit.forward(params, CFG, z, t, c)
    sage0 = dataclasses.replace(SAGE, share_ratio=0.0)
    K, N = 2, 1                                     # singleton groups
    cond = jax.random.normal(jax.random.PRNGKey(1),
                             (K, N, CFG.cond_len, CFG.cond_dim))
    mask = jnp.ones((K, N))
    null = jnp.zeros((CFG.cond_len, CFG.cond_dim))
    H = CFG.latent_size
    shared = shared_sample(eps_fn, SCHED, sage0, jax.random.PRNGKey(7), cond,
                           mask, null, (H, H, CFG.latent_channels))
    indep = independent_sample(eps_fn, SCHED, sage0, jax.random.PRNGKey(7),
                               cond.reshape(K, CFG.cond_len, CFG.cond_dim),
                               null, (H, H, CFG.latent_channels))
    np.testing.assert_allclose(
        np.asarray(shared["latents"].reshape(K, H, H, -1)),
        np.asarray(indep["latents"]), rtol=2e-2, atol=2e-3)


def test_shared_sampling_members_identical_at_full_sharing():
    params = dit.init_params(CFG, jax.random.PRNGKey(0))
    eps_fn = lambda z, t, c: dit.forward(params, CFG, z, t, c)
    sage1 = dataclasses.replace(SAGE, share_ratio=1.0)
    K, N = 1, 3
    cond = jax.random.normal(jax.random.PRNGKey(1),
                             (K, N, CFG.cond_len, CFG.cond_dim))
    mask = jnp.ones((K, N))
    null = jnp.zeros((CFG.cond_len, CFG.cond_dim))
    H = CFG.latent_size
    out = shared_sample(eps_fn, SCHED, sage1, jax.random.PRNGKey(2), cond,
                        mask, null, (H, H, CFG.latent_channels))
    lat = np.asarray(out["latents"])
    np.testing.assert_allclose(lat[:, 0], lat[:, 1], atol=1e-6)
    np.testing.assert_allclose(lat[:, 0], lat[:, 2], atol=1e-6)


def test_adaptive_branch_point_monotone():
    T = 30
    bps = [grouping.adaptive_branch_point(s, T) for s in (0.2, 0.5, 0.9)]
    assert bps[0] >= bps[1] >= bps[2]                # tighter group -> share more
