"""Continuous-batching serving scheduler + cross-batch trunk cache.

Covers: segment-resume parity with one-shot shared_sample (both samplers,
both step_impl values, multiple slice sizes — the acceptance bar),
incremental grouping invariants, the (tau_min, tau_max] convention +
group_max guard, the oversize-clique completion-mapping regression,
per-group adaptive beta, TrunkCache LRU/byte accounting, and the
streaming-vs-sync NFE win on a repeated-theme arrival trace.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.core import grouping
from repro.core import shared_sampling as ss
from repro.core.schedule import make_schedule
from repro.data.synthetic import ShapesDataset
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.engine import SageServingEngine
from repro.serving.scheduler import RequestScheduler
from repro.serving.trunk_cache import TrunkCache, TrunkEntry

SCHED = make_schedule(1000)
CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)
H = CFG.latent_size
SHAPE = (H, H, CFG.latent_channels)


def _eps_fn(z, t, c):
    return dit.forward(PARAMS, CFG, z, t, c)


def _engine(sage, **kw):
    return SageServingEngine(CFG, sage, dit_params=PARAMS,
                             text_params=TEXT_PARAMS, text_cfg=TC, **kw)


# ---------------------------------------------------------------------------
# segment-resume parity (the tentpole refactor's contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", ["ddim", "dpmpp"])
@pytest.mark.parametrize("step_impl", ["reference", "fused"])
@pytest.mark.parametrize("slice_steps", [1, 3])
def test_segment_resume_matches_one_shot(sampler, step_impl, slice_steps):
    """shared_phase/branch_phase slices of any size S must reproduce the
    one-shot shared_sample latents bitwise — including DPM-Solver++(2M),
    whose history carry crosses segment boundaries."""
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=3.0,
                      sampler=sampler, step_impl=step_impl)
    K, N = 2, 3
    cond = jax.random.normal(jax.random.PRNGKey(1),
                             (K, N, CFG.cond_len, CFG.cond_dim))
    mask = jnp.ones((K, N))
    null = jnp.zeros((CFG.cond_len, CFG.cond_dim))
    one = ss.shared_sample(_eps_fn, SCHED, sage, jax.random.PRNGKey(2),
                           cond, mask, null, SHAPE)

    T, Ts = sage.total_steps, sage.branch_point
    n_shared = T - Ts
    carry = ss.init_carry(jax.random.PRNGKey(2), K, SHAPE)
    cbar = ss.group_mean(cond, mask)
    done = 0
    while done < n_shared:
        s = min(slice_steps, n_shared - done)
        carry = ss.shared_phase(_eps_fn, SCHED, sage, carry, cbar, null, s)
        done += s
    assert int(carry.step_idx) == n_shared
    carry = ss.fork_carry(carry, N)
    cm = cond.reshape(K * N, CFG.cond_len, CFG.cond_dim)
    while done < T:
        s = min(slice_steps, T - done)
        carry = ss.branch_phase(_eps_fn, SCHED, sage, carry, cm, mask, null,
                                s, fork_idx=n_shared)
        done += s
    sliced = np.asarray(carry.z.reshape(K, N, *SHAPE))
    np.testing.assert_array_equal(sliced, np.asarray(one["latents"]))


def test_segment_nfe_helpers_match_one_shot():
    sage = SageConfig(total_steps=8, share_ratio=0.25)
    K, N = 2, 3
    mask = jnp.ones((K, N))
    n_shared = sage.total_steps - sage.branch_point
    nfe = (ss.shared_phase_nfe(K, n_shared)
           + float(ss.branch_phase_nfe(mask, sage.branch_point,
                                       sage.shared_uncond_cfg)))
    assert nfe == 2 * K * n_shared + 2 * K * N * sage.branch_point


def test_fork_carry_broadcasts_and_zeroes_history():
    carry = ss.SampleCarry(jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32
                                      ).reshape(2, 4, 4, 3),
                           jnp.ones((2, 4, 4, 3)), jnp.int32(5))
    forked = ss.fork_carry(carry, 3)
    assert forked.z.shape == (6, 4, 4, 3)
    assert int(forked.step_idx) == 5
    np.testing.assert_array_equal(np.asarray(forked.eps_prev), 0.0)
    np.testing.assert_array_equal(np.asarray(forked.z[0]),
                                  np.asarray(forked.z[2]))
    np.testing.assert_array_equal(np.asarray(forked.z[1]),
                                  np.asarray(carry.z[0]))


# ---------------------------------------------------------------------------
# grouping: tau convention, guards, incremental admission
# ---------------------------------------------------------------------------

def test_edge_mask_interval_convention():
    sim = np.array([0.3, 0.300001, 0.9, 0.95])
    m = grouping.edge_mask(sim, 0.3, 0.9)
    assert m.tolist() == [False, True, True, False]   # (tau_min, tau_max]
    with pytest.raises(ValueError):
        grouping.edge_mask(sim, 0.9, 0.9)             # empty interval


def test_greedy_clique_groups_group_max_guard():
    sim = np.eye(3)
    with pytest.raises(ValueError):
        grouping.greedy_clique_groups(sim, 0.5, group_max=0)
    with pytest.raises(ValueError):
        grouping.incremental_assign(np.ones(4), [], 0.5, group_max=0)


def test_incremental_assign_keeps_clique_invariant():
    """Arrival-order admission must satisfy the same pairwise-edge
    invariant greedy_clique_groups enforces."""
    rng = np.random.RandomState(0)
    tau, gmax = 0.3, 4
    embeds = rng.randn(30, 16)
    groups = []          # list of member index lists
    for i, e in enumerate(embeds):
        gi = grouping.incremental_assign(
            e, [embeds[g] for g in groups], tau, group_max=gmax)
        if gi >= 0:
            groups[gi].append(i)
        else:
            groups.append([i])
    flat = sorted(i for g in groups for i in g)
    assert flat == list(range(30))
    sim = grouping.similarity_matrix(embeds)
    for g in groups:
        assert 1 <= len(g) <= gmax
        for i in g:
            for j in g:
                if i != j:
                    assert sim[i, j] > tau


def test_incremental_assign_prefers_tightest_and_skips_full():
    a = np.array([1.0, 0.0, 0.0, 0.0])
    b = np.array([0.92, 0.39, 0.0, 0.0])    # cos(a,b) ~ 0.92
    new = np.array([0.99, 0.14, 0.0, 0.0])
    # two open groups: [a] (tighter for new) and [b]
    gi = grouping.incremental_assign(new, [np.stack([a]), np.stack([b])],
                                     0.5)
    assert gi == 0
    # group 0 full -> falls to group 1
    gi = grouping.incremental_assign(new, [np.stack([a] * 2), np.stack([b])],
                                     0.5, group_max=2)
    assert gi == 1
    # nothing admissible -> seed new
    gi = grouping.incremental_assign(new, [np.stack([-a])], 0.5)
    assert gi == -1


def test_flatten_groups_matches_pad_rows():
    groups = [[0, 1, 2, 3, 4, 5, 6], [7, 8]]
    flat = grouping.flatten_groups(groups, 4)
    idx, mask = grouping.pad_groups(groups, 4)
    assert flat == [[0, 1, 2, 3], [4, 5, 6], [7, 8]]
    for k, row in enumerate(flat):
        assert idx[k, :len(row)].tolist() == row
        assert mask[k].sum() == len(row)


# ---------------------------------------------------------------------------
# engine regressions: oversize-clique completion mapping, per-group beta
# ---------------------------------------------------------------------------

def test_oversize_clique_completion_mapping():
    """7-member clique packed at group_size=4 splits over two rows; every
    prompt must come back exactly once (the old engine iterated the
    *unsplit* groups and dropped/misaligned the tail rows)."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.05)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=4, group_max=7)
    base = "a small red circle on a blue background"
    prompts = [base] * 7
    done = sched.run_batch(prompts)
    assert len(done) == 7
    assert sorted(c.prompt for c in done) == sorted(prompts)
    assert len({c.group_id for c in done}) == 2       # 4 + 3 packed rows
    assert sched.stats["completed"] == 7


def test_adaptive_beta_is_per_group():
    """A singleton group (min-sim pinned to 1.0) must not drag other
    groups' beta bucket: NFE must equal the per-group-bucket sum."""
    sage = SageConfig(total_steps=10, share_ratio=0.3, guidance_scale=2.0,
                      tau_min=0.5, adaptive_branch=True)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=4, branch_buckets=(0.2, 0.3, 0.4))
    # controlled similarity space: a pair at cos=0.6 and an unrelated
    # singleton
    pooled = np.array([[1.0, 0.0], [0.6, 0.8], [0.0, -1.0]], np.float32)
    conds = np.asarray(jax.random.normal(
        jax.random.PRNGKey(3), (3, CFG.cond_len, CFG.cond_dim)))
    sched._embed = lambda prompts: (conds[:len(prompts)],
                                    pooled[:len(prompts)])
    done = sched.run_batch(["p0", "p1", "p2"], adaptive=True)
    assert len(done) == 3
    # groups: {0,1} (cos 0.6 -> beta_raw 0.3 -> bucket 0.3, Ts=7) and {2}
    # (singleton -> beta_raw 0.5 -> bucket 0.4, Ts=6)
    expect = (2 * 1 * 3 + 2 * 2 * 7) + (2 * 1 * 4 + 2 * 1 * 6)
    assert sched.stats["nfe"] == expect
    # the old batch-mean bucket (mean(0.6, 1.0)*0.5 -> 0.4 for BOTH groups)
    # would have produced a different total
    old = (2 * 1 * 4 + 2 * 2 * 6) + (2 * 1 * 4 + 2 * 1 * 6)
    assert expect != old


# ---------------------------------------------------------------------------
# trunk cache
# ---------------------------------------------------------------------------

def _entry(centroid, beta=0.3, cfg_key=("k",), shape=(1, 4, 4, 3), fill=0.0):
    z = np.full(shape, fill, np.float32)
    return TrunkEntry(z=z, eps_prev=np.zeros_like(z), step_idx=2,
                      beta_bucket=beta, rng_fold=0,
                      centroid=np.asarray(centroid, np.float32),
                      cfg_key=cfg_key)


def test_trunk_cache_exact_and_cosine_hits():
    c = TrunkCache(tau_trunk=0.9)
    e = _entry([1.0, 0.0, 0.0])
    c.insert(e, shape=(1, 4, 4, 3))
    # exact quantized-key hit
    hit = c.lookup([1.0, 0.0, 0.0], 0.3, ("k",), (1, 4, 4, 3))
    assert hit is e and c.stats["exact_hits"] == 1
    # near-duplicate cosine hit (rounded key differs)
    hit = c.lookup([0.98, 0.199, 0.0], 0.3, ("k",), (1, 4, 4, 3))
    assert hit is e
    # below tau_trunk -> miss
    assert c.lookup([0.0, 1.0, 0.0], 0.3, ("k",), (1, 4, 4, 3)) is None
    # bucket / cfg / shape mismatches -> miss even at cosine 1.0
    assert c.lookup([1.0, 0.0, 0.0], 0.2, ("k",), (1, 4, 4, 3)) is None
    assert c.lookup([1.0, 0.0, 0.0], 0.3, ("other",), (1, 4, 4, 3)) is None
    assert c.lookup([1.0, 0.0, 0.0], 0.3, ("k",), (1, 8, 8, 3)) is None


def test_trunk_cache_lru_byte_budget():
    shape = (1, 4, 4, 3)
    nbytes = int(np.prod(shape)) * 4 * 2              # z + eps_prev
    cache = TrunkCache(tau_trunk=0.99, max_bytes=3 * nbytes)
    dirs = np.eye(4, 8)
    for i in range(3):
        cache.insert(_entry(dirs[i], fill=float(i)), shape=shape)
    assert len(cache) == 3 and cache.bytes == 3 * nbytes
    # touch entry 0 -> entry 1 becomes LRU
    assert cache.lookup(dirs[0], 0.3, ("k",), shape) is not None
    cache.insert(_entry(dirs[3]), shape=shape)
    assert len(cache) == 3
    assert cache.stats["evictions"] == 1
    assert cache.lookup(dirs[1], 0.3, ("k",), shape) is None   # evicted
    assert cache.lookup(dirs[0], 0.3, ("k",), shape) is not None
    # replacing the same key does not double-count bytes
    cache.insert(_entry(dirs[0], fill=9.0), shape=shape)
    assert cache.bytes == 3 * nbytes


def test_trunk_cache_overwrite_byte_accounting():
    """Overwriting an existing exact key must be evict-then-insert: the
    ledger ``bytes`` always equals the recount over stored entries — no
    double-count, under budget pressure, across store_history modes, and
    for same-object re-inserts."""
    shape = (1, 4, 4, 3)
    nbytes = int(np.prod(shape)) * 4 * 2
    c = TrunkCache(tau_trunk=0.9, max_bytes=2 * nbytes)
    c.insert(_entry([1.0, 0.0], fill=1.0), shape=shape)
    for fill in (2.0, 3.0, 4.0):              # repeated same-key overwrite
        c.insert(_entry([1.0, 0.0], fill=fill), shape=shape)
        assert c.bytes == c.ledger_bytes() == nbytes
    assert len(c) == 1 and c.stats["overwrites"] == 3
    assert c.stats["evictions"] == 0          # overwrite is not an eviction
    # overwrite while a second entry sits at the budget edge
    c.insert(_entry([0.0, 1.0], fill=5.0), shape=shape)
    c.insert(_entry([1.0, 0.0], fill=6.0), shape=shape)
    assert len(c) == 2 and c.bytes == c.ledger_bytes() == 2 * nbytes
    # same-object re-insert must not double-count either
    e = _entry([0.7071, 0.7071], fill=7.0)
    slim = TrunkCache(tau_trunk=0.9, store_history=False)
    slim.insert(e, shape=shape)
    slim.insert(e, shape=shape)
    assert len(slim) == 1
    assert slim.bytes == slim.ledger_bytes() == nbytes // 2


def test_trunk_cache_overwrite_fuzz_ledger():
    """Randomized insert/lookup/overwrite sequence: the incremental byte
    ledger must track the recount exactly at every step."""
    rng = np.random.RandomState(0)
    dirs = rng.randn(6, 8)
    for store_history in (True, False):
        c = TrunkCache(tau_trunk=0.9, max_bytes=5 * 384,
                       store_history=store_history)
        for step in range(200):
            d = dirs[rng.randint(6)]
            if rng.rand() < 0.7:
                c.insert(_entry(d, fill=float(step)), shape=(1, 4, 4, 3))
            else:
                c.lookup(d, 0.3, ("k",), (1, 4, 4, 3))
            assert c.bytes == c.ledger_bytes(), (step, store_history)
        assert (c.stats["inserts"]
                == len(c) + c.stats["evictions"] + c.stats["overwrites"])


def test_trunk_cache_validates_tau():
    with pytest.raises(ValueError):
        TrunkCache(tau_trunk=0.0)


def test_trunk_cache_exact_key_still_enforces_tau():
    """Coarse quantization can collide centroids whose true cosine is
    below tau_trunk; the exact-key fast path must not bypass the check."""
    c = TrunkCache(tau_trunk=0.95, quant_decimals=0)
    c.insert(_entry([0.9, 0.436]), shape=(1, 4, 4, 3))
    # [1, 0] quantizes to the same key but cos ~ 0.9 < 0.95 -> miss
    assert c.lookup([1.0, 0.0], 0.3, ("k",), (1, 4, 4, 3)) is None
    assert c.lookup([0.9, 0.436], 0.3, ("k",), (1, 4, 4, 3)) is not None


@pytest.mark.parametrize("index", ["scan", "lsh"])
def test_trunk_cache_collision_falls_through_to_similarity(index):
    """Directed regression: a quantized-key collision whose resident
    entry fails the cosine re-check must fall through to the similarity
    search, not return a miss — the colliding entry cannot be allowed to
    mask a compatible near-duplicate stored under a different key."""
    shape = (1, 4, 4, 3)
    c = TrunkCache(tau_trunk=0.95, quant_decimals=0, index=index)
    # stored under quant key (1, 0): cos to the query ~ 0.958 >= tau
    c.insert(_entry([0.970, 0.242], fill=1.0), shape=shape)
    # stored under quant key (1, 1): cos to the query ~ 0.88 < tau
    c.insert(_entry([0.515, 0.857], fill=2.0), shape=shape)
    assert len(c) == 2
    # query quantizes to (1, 1) -> exact-key path finds the *far* entry,
    # fails the re-check, and must still locate the near one by search
    hit = c.lookup([0.86, 0.51], 0.3, ("k",), shape)
    assert hit is not None, "collision masked a compatible near-duplicate"
    assert float(np.asarray(hit.z).ravel()[0]) == 1.0
    assert c.stats["hits"] == 1 and c.stats["exact_hits"] == 0


def test_trunk_cache_payload_namespaces():
    """ar_prefix and diffusion-trunk payloads share the cache but can
    never satisfy each other's lookups, even with identical centroids."""
    shape = (1, 4, 4, 3)
    c = TrunkCache(tau_trunk=0.9)
    e = _entry([1.0, 0.0])
    e.payload = "ar_prefix"
    c.insert(e, shape=shape)
    assert c.lookup([1.0, 0.0], 0.3, ("k",), shape,
                    payload="trunk") is None
    assert c.lookup([1.0, 0.0], 0.3, ("k",), shape,
                    payload="ar_prefix") is not None


def test_trunk_cache_tier_spill_and_promote():
    """HBM overflow spills LRU entries to the host tier (bytes conserved
    across the move), and a host hit promotes back to HBM — with the
    per-tier ledgers balancing throughout."""
    shape = (1, 4, 4, 3)
    per = 2 * int(np.prod(shape)) * 4            # z + eps_prev
    dirs = np.eye(4, dtype=np.float32)
    c = TrunkCache(tau_trunk=0.9, max_bytes=2 * per,
                   host_bytes=10 * per)
    for i in range(4):
        c.insert(_entry(dirs[i], fill=float(i)), shape=shape)
    # 4 inserts into a 2-entry HBM budget: two spills, nothing evicted
    assert len(c) == 4 and c.stats["spills"] == 2
    assert c.stats["evictions"] == 0
    assert c.tier_bytes == {"hbm": 2 * per, "host": 2 * per}
    assert c.tier_bytes == c.tier_ledger()
    assert c.bytes == c.ledger_bytes() == 4 * per
    # entry 0 spilled first (LRU); a hit on it promotes it back,
    # displacing the coldest HBM resident
    hit = c.lookup(dirs[0], 0.3, ("k",), shape)
    assert hit is not None and hit.tier == "hbm"
    assert c.stats["promotions"] == 1 and c.stats["spills"] == 3
    assert c.tier_bytes == c.tier_ledger()
    assert c.bytes == c.ledger_bytes() == 4 * per
    # promoted payloads come back as device arrays
    import jax
    assert isinstance(hit.z, jax.Array)


def test_trunk_cache_host_budget_evicts_for_real():
    """Host-tier overflow is terminal: the spill tier's own budget
    evicts, and with host_bytes=0 HBM overflow evicts directly (the
    pre-tier behavior)."""
    shape = (1, 4, 4, 3)
    per = 2 * int(np.prod(shape)) * 4
    dirs = np.eye(6, dtype=np.float32)
    c = TrunkCache(tau_trunk=0.9, max_bytes=2 * per, host_bytes=1 * per)
    for i in range(6):
        c.insert(_entry(dirs[i], fill=float(i)), shape=shape)
    assert len(c) == 3                           # 2 hbm + 1 host
    assert c.stats["spills"] == 4 and c.stats["evictions"] == 3
    assert c.tier_bytes == c.tier_ledger() == {"hbm": 2 * per,
                                               "host": 1 * per}
    flat = TrunkCache(tau_trunk=0.9, max_bytes=2 * per)   # host disabled
    for i in range(6):
        flat.insert(_entry(dirs[i], fill=float(i)), shape=shape)
    assert len(flat) == 2 and flat.stats["spills"] == 0
    assert flat.stats["evictions"] == 4
    assert flat.tier_bytes == {"hbm": 2 * per, "host": 0}


def test_trunk_cache_store_history_flag_halves_bytes():
    shape = (1, 4, 4, 3)
    z_bytes = int(np.prod(shape)) * 4
    full = TrunkCache(tau_trunk=0.9)
    full.insert(_entry([1.0, 0.0]), shape=shape)
    slim = TrunkCache(tau_trunk=0.9, store_history=False)
    slim.insert(_entry([1.0, 0.0]), shape=shape)
    assert full.bytes == 2 * z_bytes and slim.bytes == z_bytes
    hit = slim.lookup([1.0, 0.0], 0.3, ("k",), shape)
    assert hit is not None and hit.eps_prev is None


# ---------------------------------------------------------------------------
# streaming scheduler end-to-end
# ---------------------------------------------------------------------------

def _wave_prompts(n=3):
    _, prompts = ShapesDataset(res=16).batch(0, n)
    return prompts


def test_streaming_singleton_launches_after_max_wait():
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=4, slice_steps=2, max_wait_ticks=2)
    sched.submit(_wave_prompts(1), now=0.0)
    assert sched.tick(now=1.0) == []                   # waiting for peers
    assert sched.open_groups and not sched.inflight
    done = []
    t = 1.0
    while sched.pending:
        t += 1.0
        done.extend(sched.tick(now=t))
    assert len(done) == 1
    assert done[0].latency > 0
    s = sched.summary()
    assert s["completed"] == 1 and s["latency_p50"] > 0


def test_streaming_deadline_forces_launch():
    """An approaching (still meetable) deadline launches a sub-full group
    ahead of ``max_wait_ticks``.  An *already-expired* deadline no longer
    reaches this path at all — it is refused at admission with
    ``status='rejected_expired'`` (see tests/test_qos.py)."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=4, slice_steps=4, max_wait_ticks=50)
    sched.submit(_wave_prompts(1), now=0.0, deadline=3.0)
    sched.tick(now=1.0)                                # deadline far: held
    assert sched.open_groups and not sched.inflight
    sched.tick(now=2.0)
    sched.tick(now=3.0)                                # deadline reached ->
    assert not sched.open_groups and sched.inflight    # launched despite
    #                                                   being 1/4 full


def test_streaming_cache_beats_sync_on_repeated_theme():
    """Acceptance: on a repeated-theme arrival trace the trunk-cache path
    must spend strictly fewer NFE than the synchronous engine serving the
    same waves, and the saving must show up in the stats."""
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=2.0,
                      tau_min=0.2)
    prompts = _wave_prompts(3)
    waves = 3

    sync = _engine(sage, group_size=3)
    for _ in range(waves):                             # arrivals over time:
        sync.submit(prompts)                           # one batch per wave
        sync.step(max_batch=len(prompts))
    nfe_sync = sync.stats["nfe"]

    stream = _engine(sage, group_size=3).streaming_scheduler(
        slice_steps=2, max_wait_ticks=1, trunk_cache=TrunkCache(
            tau_trunk=0.9))
    t, done = 0.0, []
    for _ in range(waves):
        stream.submit(prompts, now=t)
        while stream.pending:
            t += 1.0
            done.extend(stream.tick(now=t))
    assert len(done) == waves * len(prompts)
    assert stream.stats["nfe"] < nfe_sync              # strict NFE win
    assert stream.stats["nfe_saved_cache"] > 0
    assert stream.trunk_cache.stats["hits"] >= waves - 1
    assert any(c.cache_hit for c in done)
    assert all(np.isfinite(c.image).all() for c in done)
    # NFE accounting closes: sync spend == stream spend + cached savings
    assert nfe_sync == stream.stats["nfe"] + stream.stats["nfe_saved_cache"]


def test_streaming_matches_sync_nfe_without_cache():
    """No cache, arrivals in one burst: the tick loop is the synchronous
    path run in slices — identical grouping, identical NFE."""
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=2.0,
                      tau_min=0.2)
    prompts = _wave_prompts(4)

    sync = _engine(sage, group_size=4)
    sync.submit(prompts)
    sync.step(max_batch=len(prompts))

    stream = _engine(sage, group_size=4).streaming_scheduler(
        slice_steps=2, max_wait_ticks=1)
    stream.submit(prompts, now=0.0)
    done = []
    t = 0.0
    while stream.pending:
        t += 1.0
        done.extend(stream.tick(now=t))
    assert len(done) == len(prompts)
    assert stream.stats["nfe"] == sync.stats["nfe"]
    assert stream.stats["nfe_independent"] == sync.stats["nfe_independent"]
