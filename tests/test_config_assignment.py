"""The full configs must match the assignment sheet exactly."""
import pytest

from repro.config import get_config

SPEC = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    "mamba2-780m": (48, 1536, None, None, 0, 50280),
    "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
    "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, None, 163840),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, None, 102400),
}


@pytest.mark.parametrize("arch", sorted(SPEC))
def test_assignment_numbers(arch):
    L, d, h, kv, ff, v = SPEC[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    if h is not None:
        assert cfg.n_heads == h
        assert cfg.n_kv_heads == kv
    if ff:
        assert cfg.d_ff == ff
    assert cfg.vocab == v


def test_assignment_extras():
    assert get_config("mamba2-780m").ssm.d_state == 128
    assert get_config("deepseek-v2-lite-16b").mla.kv_lora_rank == 512
    k = get_config("kimi-k2-1t-a32b").moe
    assert (k.n_routed, k.top_k, k.d_ff_expert) == (384, 8, 2048)
    d = get_config("deepseek-v2-lite-16b").moe
    assert (d.n_routed, d.top_k, d.n_shared, d.d_ff_expert) == (64, 6, 2, 1408)
    assert get_config("qwen1.5-32b").qkv_bias
    assert get_config("qwen3-32b").qk_norm
    rg = get_config("recurrentgemma-2b")
    assert rg.pattern == ("rglru", "rglru", "local_attn")
    sm = get_config("seamless-m4t-large-v2")
    assert sm.enc_layers == 24 and sm.family == "encdec"
    vl = get_config("llama-3.2-vision-11b")
    assert vl.family == "vlm" and vl.pattern.count("cross_attn") == 1
    # 1T-param check for the paper-table MoE
    assert 0.95e12 < get_config("kimi-k2-1t-a32b").n_params() < 1.1e12
