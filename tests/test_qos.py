"""QoS classes, preemptive scheduling and load shedding.

Covers: launch-order comparators, the expired-deadline admission bugfix
(directed regression), class-compartmented grouping, WFQ slot splitting
with preemption / resume / the no-starvation bound, shed and degrade
admission verdicts (status + per-class stats + conservation), the
adaptive pad-aware hold budget, and the PR-5 equivalence criterion: with
a single QoS class, ``preempt=False`` (or no deadlines) and no faults,
the scheduler's output is bitwise-identical to the plain EDF tick loop.
"""
import jax
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.policies import (DEFAULT_QOS, QOS_RANK, LaunchContext,
                                    AdaptivePadAwarePolicy,
                                    SaturationAdmission, AdmissionContext,
                                    make_launch_order, order_edf,
                                    order_fifo, order_qos_edf)
from repro.serving.scheduler import RequestScheduler

CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)

SAGE = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                  tau_min=0.2)


def _sched(**kw):
    kw.setdefault("group_size", 2)
    kw.setdefault("slice_steps", 2)
    return RequestScheduler(CFG, SAGE, PARAMS, TEXT_PARAMS, TC, **kw)


def _run(sched, max_ticks=200, start=0.0):
    done, t = [], start
    while sched.pending and t < start + max_ticks:
        t += 1.0
        done.extend(sched.tick(now=t))
    return done


# ---------------------------------------------------------------------------
# launch-order comparators
# ---------------------------------------------------------------------------

class _G:
    def __init__(self, gid, qos=DEFAULT_QOS, deadline=None):
        self.gid, self.qos, self._dl = gid, qos, deadline

    def earliest_deadline(self):
        return float("inf") if self._dl is None else self._dl


def test_launch_order_comparators():
    a = _G(0, "batch", deadline=5.0)
    b = _G(1, "interactive", deadline=9.0)
    c = _G(2, "interactive")
    gs = [a, b, c]
    assert sorted(gs, key=order_fifo) == [a, b, c]
    assert sorted(gs, key=order_edf) == [a, b, c]          # EDF: 5 < 9 < inf
    # qos_edf: interactive outranks batch regardless of deadline
    assert sorted(gs, key=order_qos_edf) == [b, c, a]
    # single class -> qos_edf degenerates to edf exactly
    one = [_G(i, "batch", d) for i, d in enumerate([7.0, None, 3.0])]
    assert [g.gid for g in sorted(one, key=order_qos_edf)] == \
        [g.gid for g in sorted(one, key=order_edf)]


def test_make_launch_order_resolution():
    assert make_launch_order(None) is order_qos_edf
    assert make_launch_order("fifo") is order_fifo
    custom = lambda g: (g.gid,)                                  # noqa: E731
    assert make_launch_order(custom) is custom
    with pytest.raises(ValueError, match="unknown launch order"):
        make_launch_order("lifo")


def test_submit_validates_qos():
    s = _sched()
    with pytest.raises(ValueError, match="unknown qos"):
        s.submit(["a cat"], now=0.0, qos="platinum")
    with pytest.raises(ValueError, match="length"):
        s.submit(["a cat"], now=0.0, qos=["interactive", "batch"])
    with pytest.raises(ValueError, match="qos_weights"):
        _sched(qos_weights={"interactive": 0})


# ---------------------------------------------------------------------------
# expired-deadline admission (the satellite bugfix, directed regression)
# ---------------------------------------------------------------------------

def test_expired_deadline_rejected_at_admission():
    """A request whose deadline has already passed — or expires within
    one segment, so even an immediate solo launch cannot meet it — must
    be refused up front with its own status, not churn through grouping
    and launch (the pre-PR-6 behavior launched it anyway)."""
    s = _sched()
    s.submit(["too late"], now=0.0, deadline=0.5)           # already past
    s.submit(["one tick short"], now=0.0, deadline=1.9)     # < now+1 at t=1
    out = s.tick(now=1.0)
    assert [c.status for c in out] == ["rejected_expired"] * 2
    assert all(c.image is None and c.group_id == -1 for c in out)
    assert s.stats["rejected_expired"] == 2
    assert s.class_stats[DEFAULT_QOS]["rejected_expired"] == 2
    # nothing leaked into the service path
    assert not s.open_groups and not s.inflight and s.pending == 0
    assert s.stats["launches"] == 0
    # conservation closes through the refusal ledger
    assert s.stats["requests"] == s.stats["completed"] + s.stats["shed"] \
        + s.stats["rejected_expired"] + s.pending


def test_meetable_deadline_still_served():
    s = _sched()
    s.submit(["plenty of time"], now=0.0, deadline=50.0)
    done = _run(s)
    assert [c.status for c in done] == ["ok"]
    assert s.stats["rejected_expired"] == 0
    assert s.stats["deadline_met"] == 1


# ---------------------------------------------------------------------------
# class compartments
# ---------------------------------------------------------------------------

def test_groups_never_mix_qos_classes():
    s = _sched(group_size=4)
    # identical prompts -> maximal similarity: only the class keeps them
    # apart
    s.submit(["a red circle", "a red circle"], now=0.0, qos="interactive")
    s.submit(["a red circle", "a red circle"], now=0.0, qos="batch")
    done = _run(s)
    assert len(done) == 4
    by_gid = {}
    for c in done:
        by_gid.setdefault(c.group_id, set()).add(c.qos)
    assert len(by_gid) == 2
    for classes in by_gid.values():
        assert len(classes) == 1


def test_degraded_never_groups_with_full_quality():
    """Degrade-mode admission must not let a draft-NFE member drag a
    full-quality group (or vice versa): compartments are (qos, degraded).
    With a one-group saturation horizon, the first request of a theme is
    admitted clean and every later one degrades — identical prompts, so
    only the compartment keeps them in separate groups."""
    s = _sched(group_size=4, max_groups_per_tick=1, admission="degrade")
    s.admission.horizon_ticks = 2.0       # < one group's drain ticks
    s.admission.interactive_headroom = 1.0
    s.submit(["a red circle v1", "a red circle v2"], now=0.0)
    s.tick(now=1.0)
    s.submit(["a red circle v3"], now=1.0)   # joins the *degraded* group
    done = _run(s, start=1.0)
    by_status = {}
    for c in done:
        by_status.setdefault(c.status, []).append(c)
    assert [c.prompt for c in by_status.get("ok", [])] == ["a red circle v1"]
    assert sorted(c.prompt for c in by_status.get("degraded", [])) == \
        ["a red circle v2", "a red circle v3"]
    ok_gid = by_status["ok"][0].group_id
    deg_gids = {c.group_id for c in by_status["degraded"]}
    assert deg_gids == {by_status["degraded"][0].group_id}  # mates grouped
    assert ok_gid not in deg_gids           # never with full quality
    assert s.stats["degraded"] == 2
    assert s.class_stats[DEFAULT_QOS]["degraded"] == 2


def test_degraded_runs_at_draft_tier_budget():
    """DEGRADE is now a quality-TIER downgrade: the admitted request runs
    at the ``degrade_tier`` step budget (its own shorter DDIM grid), not
    at a forced beta bucket — beta stays on the similarity rule."""
    s = _sched(admission="degrade")
    s.admission.horizon_ticks = 0.5
    s.admission.interactive_headroom = 1.0
    s.submit(["backlog filler one", "backlog filler two"], now=0.0)
    s.tick(now=1.0)
    s.submit(["degraded arrival"], now=1.0)
    s.tick(now=2.0)
    degraded = [g for g in s.open_groups + s.inflight if g.degraded]
    assert degraded
    done = _run(s, start=2.0)
    assert degraded[0].tier == s.degrade_tier == "draft"
    assert degraded[0].total_steps == s.tiers["draft"] \
        < s.tiers["standard"]
    # beta is NOT forced anymore — it follows the similarity rule
    assert degraded[0].beta in s.branch_buckets \
        or degraded[0].beta == s.sage.share_ratio
    deg = [c for c in done if c.status == "degraded"]
    ok = [c for c in done if c.status == "ok"]
    assert deg and ok
    # the NFE saving comes from the tier budget
    assert max(c.nfe_share for c in deg) < min(c.nfe_share for c in ok)
    # tier ledger saw both tiers
    assert s.tier_stats["draft"]["completed"] == len(deg)
    assert s.tier_stats["standard"]["completed"] == len(ok)


def test_degraded_copacks_with_standard_launch():
    """The degrade-unification regression: a degraded (draft-tier) group
    and a standard-tier group must share ONE stacked launch whenever
    their segments line up — the old forced-max-beta design pushed the
    degraded group to a different phase boundary and broke co-packing.
    Distinct themes keep them in separate groups; per-row grids let them
    ride one branch pack."""
    s = _sched(admission="degrade", slice_steps=1, group_size=2,
               max_wait_ticks=0, packed=True)
    s.submit(["a red circle"], now=0.0)
    s.tick(now=1.0)                       # standard group in flight
    s.admission.horizon_ticks = 0.01      # saturate: next arrival degrades
    s.admission.interactive_headroom = 1.0
    s.submit(["a blue square totally different"], now=1.0)
    s.tick(now=2.0)
    assert any(g.degraded for g in s.open_groups + s.inflight)
    copacked = False
    t = 2.0
    while s.pending and t < 40.0:
        t += 1.0
        pre = s.stats["launches"]
        infl = [(g.tier, g.state) for g in s.inflight]   # pre-tick states
        s.tick(now=t)
        advanced = s.stats["launches"] - pre
        tiers_in_branch = {tr for tr, st in infl if st == "branch"}
        if len(tiers_in_branch) == 2 and advanced == 1:
            copacked = True               # two tiers, one stacked launch
    assert copacked, "draft + standard groups never shared a launch"


# ---------------------------------------------------------------------------
# WFQ, preemption, resume, starvation bound
# ---------------------------------------------------------------------------

def test_preemption_and_resume_under_fifo_order():
    """FIFO order puts the older batch group first in the capped prefix;
    preemption lets the deadline-at-risk interactive group claim the
    slot, the displaced batch group parks (counted), then resumes."""
    s = _sched(max_groups_per_tick=1, launch_order="fifo",
               max_wait_ticks=0)
    s.submit(["batch job"], now=0.0, qos="batch")
    s.tick(now=1.0)                              # batch launched + advancing
    assert len(s.inflight) == 1
    ttf = s._ticks_to_finish()
    s.submit(["urgent request"], now=1.0, deadline=1.0 + ttf + 2.0,
             qos="interactive")
    done = _run(s, start=1.0)
    assert sorted(c.qos for c in done) == ["batch", "interactive"]
    assert s.stats["preemptions"] >= 1
    assert s.stats["resumes"] >= 1
    assert s.class_stats["batch"]["preemptions"] >= 1
    # the interactive deadline was actually protected
    it = [c for c in done if c.qos == "interactive"][0]
    assert s.stats["deadline_missed"] == 0, it.latency


def test_no_preemption_when_disabled():
    s = _sched(max_groups_per_tick=1, launch_order="fifo",
               max_wait_ticks=0, preempt=False)
    s.submit(["batch job"], now=0.0, qos="batch")
    s.tick(now=1.0)
    s.submit(["urgent request"], now=1.0, deadline=4.0, qos="interactive")
    _run(s, start=1.0)
    assert s.stats["preemptions"] == 0 and s.stats["resumes"] == 0


def test_starvation_bound_forces_batch_through():
    """A continuous stream of at-risk interactive work exactly fills the
    capped slots (1 arrival/tick, 2 advance-ticks each, cap 2), so
    WITHOUT the bound batch would never advance again; the
    ``starvation_ticks`` bound forces it through, and no group is ever
    skipped for more than the bound."""
    s = _sched(max_groups_per_tick=2, max_wait_ticks=0, slice_steps=4,
               starvation_ticks=3, qos_weights={"interactive": 10**6,
                                                "batch": 1})
    assert s._ticks_to_finish() == 2
    s.submit(["batch underdog"], now=0.0, qos="batch")
    t, starved, done = 0.0, 0, []
    for i in range(20):
        t += 1.0
        # fresh tight-deadline interactive arrival every tick keeps both
        # slots claimed by the at-risk pass
        s.submit([f"urgent {i}"], now=t,
                 deadline=t + s._ticks_to_finish() + 1.5)
        done.extend(s.tick(now=t))
        for g in s.inflight:
            starved = max(starved, g.starved_ticks)
            assert g.starved_ticks <= s.starvation_ticks, (t, g.qos)
    done.extend(s.drain(now=t))
    assert "batch underdog" in [c.prompt for c in done]
    assert starved > 0                       # the bound actually engaged
    assert s.stats["preemptions"] >= 1


def test_wfq_split_honours_weights():
    """Deadline-free traffic under a cap: slots split by qos_weights via
    deficit round-robin, so with weights 2:1 interactive drains roughly
    twice as fast (measured by completion order, not starvation)."""
    s = _sched(max_groups_per_tick=3, max_wait_ticks=0,
               qos_weights={"interactive": 2, "batch": 1})
    for i in range(6):
        s.submit([f"interactive item {i}"], now=0.0, qos="interactive")
        s.submit([f"batch item {i}"], now=0.0, qos="batch")
    done = _run(s)
    assert len(done) == 12
    first_half = done[:6]
    ints = sum(1 for c in first_half if c.qos == "interactive")
    assert ints >= 4                         # weighted share showed up


# ---------------------------------------------------------------------------
# shed admission: statuses + conservation
# ---------------------------------------------------------------------------

def test_shed_past_saturation_with_interactive_headroom():
    s = _sched(max_groups_per_tick=1, max_wait_ticks=0, admission="shed")
    s.admission.horizon_ticks = float(s._ticks_to_finish())
    s.admission.interactive_headroom = 3.0
    t, done = 0.0, []
    for i in range(12):
        t += 1.0
        s.submit([f"int {i}"], now=t, qos="interactive")
        s.submit([f"bat {i}"], now=t, qos="batch")
        done.extend(s.tick(now=t))
    done.extend(s.drain(now=t))
    st = {}
    for c in done:
        st.setdefault((c.qos, c.status), []).append(c)
    # batch shed first (headroom protects interactive)
    assert len(st.get(("batch", "shed"), [])) > \
        len(st.get(("interactive", "shed"), []))
    # every shed is accounted: conservation closes exactly
    assert s.stats["requests"] == s.stats["completed"] + s.stats["shed"] \
        + s.stats["shed_faulted"] + s.stats["rejected_expired"] + s.pending
    assert s.pending == 0
    assert len(done) == s.stats["requests"]
    # summary mirrors the ledger per class
    out = s.summary()
    assert out["shed"] == s.stats["shed"]
    assert out["batch_shed"] == len(st.get(("batch", "shed"), []))
    assert out["goodput"] == s.stats["deadline_met"]


def test_saturation_admission_decide_unit():
    pol = SaturationAdmission(horizon_ticks=4.0, interactive_headroom=2.0)
    ctx = lambda qos, backlog: AdmissionContext(                 # noqa: E731
        now=0.0, qos=qos, deadline=None, backlog_ticks=backlog,
        ticks_to_finish=3, arrival_rate=1.0)
    assert pol.decide(ctx("batch", 3.9)) == "admit"
    assert pol.decide(ctx("batch", 4.1)) == "shed"
    assert pol.decide(ctx("interactive", 7.9)) == "admit"
    assert pol.decide(ctx("interactive", 8.1)) == "shed"
    with pytest.raises(ValueError):
        SaturationAdmission(horizon_ticks=0)
    with pytest.raises(ValueError):
        SaturationAdmission(mode="explode")


# ---------------------------------------------------------------------------
# adaptive pad-aware hold budget
# ---------------------------------------------------------------------------

def _ctx(arrival_rate, group_size=4):
    return LaunchContext(
        now=0.0, tick=0, group_size=group_size, max_wait_ticks=2,
        deadline_slack=0.0, ticks_to_finish=3,
        inflight_signatures=frozenset(), signature_of=lambda g: None,
        arrival_rate=arrival_rate)


def test_adaptive_hold_budget_tracks_arrival_rate():
    class FakeGroup:
        members = [None]                     # 1 member -> need 3 more

    pol = AdaptivePadAwarePolicy(hold_max=4, min_rate=0.25)
    g = FakeGroup()
    assert pol._hold_budget(g, _ctx(0.0)) == 0        # dried up: no hold
    assert pol._hold_budget(g, _ctx(0.1)) == 0        # below min_rate
    assert pol._hold_budget(g, _ctx(1.0)) == 3        # ceil(3/1)
    assert pol._hold_budget(g, _ctx(3.0)) == 1        # brisk: short hold
    assert pol._hold_budget(g, _ctx(0.5)) == 4        # capped at hold_max
    with pytest.raises(ValueError):
        AdaptivePadAwarePolicy(min_rate=0.0)


def test_adaptive_policy_end_to_end():
    """Sanity: the adaptive policy serves a staggered trace completely
    and never spends more NFE than eager (same contract as pad_aware)."""
    def run(policy):
        s = _sched(group_size=3, policy=policy, max_wait_ticks=1)
        done, t = [], 0.0
        for i in range(6):
            t += 1.0
            s.submit([f"a red circle no {i}"], now=t)
            done.extend(s.tick(now=t))
        done.extend(s.drain(now=t))
        assert s.pending == 0
        return s, done

    se, de = run("eager")
    sa, da = run("adaptive")
    assert sorted(c.prompt for c in da) == sorted(c.prompt for c in de)
    assert sa.stats["nfe"] <= se.stats["nfe"]


# ---------------------------------------------------------------------------
# PR-5 equivalence: the overload layer is invisible when unused
# ---------------------------------------------------------------------------

def test_single_class_reduces_to_plain_edf():
    """Acceptance criterion: with a single QoS class, no faults and no
    preemption pressure, the QoS scheduler's completions are bitwise
    identical to the PR-5 rule (EDF sort, plain capped prefix)."""
    rng = np.random.RandomState(0)
    trace = [(f"a {w} variant {i}", float(rng.randint(6, 20)))
             for i, w in enumerate(["red circle", "blue square",
                                    "green triangle", "red circle",
                                    "blue square", "green triangle"])]

    def run(**kw):
        s = _sched(group_size=3, max_groups_per_tick=2, **kw)
        done, t = [], 0.0
        for i, (p, dl) in enumerate(trace):
            t += 1.0
            s.submit([p], now=t, deadline=t + dl)
            done.extend(s.tick(now=t))
        done.extend(s.drain(now=t))
        assert s.pending == 0
        return done

    ref = run(launch_order="edf", preempt=False)         # the PR-5 rule
    qos = run(preempt=False)                             # qos_edf default
    assert [c.prompt for c in ref] == [c.prompt for c in qos]
    assert [c.group_id for c in ref] == [c.group_id for c in qos]
    for a, b in zip(ref, qos):
        assert np.array_equal(a.image, b.image)
        assert a.status == b.status == "ok"
    # with preemption ON, completion *order* may differ (at-risk claims
    # reorder advance slots) but every result is still bitwise identical
    # — composition and init noise depend only on admission, never on
    # slot timing
    pre = run()
    by_prompt = {c.prompt: c for c in ref}
    assert sorted(c.prompt for c in pre) == sorted(by_prompt)
    for c in pre:
        assert np.array_equal(c.image, by_prompt[c.prompt].image)
