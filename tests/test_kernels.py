"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.ddim_step.ops import fused_cfg_ddim_step
from repro.kernels.ddim_step.ref import fused_cfg_ddim_step_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.group_mean.ops import masked_group_mean
from repro.kernels.group_mean.ref import masked_group_mean_ref


# ---------------------------------------------------------------------------
# ddim_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 8, 8, 4), (1, 64, 64, 4), (3, 17, 5, 3),
                                   (4, 32, 32, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ddim_step_kernel(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    z, eu, ec = (jax.random.normal(jax.random.fold_in(key, i), shape, dtype)
                 for i in range(3))
    args = dict(guidance=7.5, a_t=0.7, s_t=0.714, a_n=0.9, s_n=0.436)
    out = fused_cfg_ddim_step(z, eu, ec, **args)
    ref = fused_cfg_ddim_step_ref(z, eu, ec, **args)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@given(st.integers(1, 4), st.integers(1, 40), st.floats(1.0, 10.0))
@settings(max_examples=10, deadline=None)
def test_ddim_step_property(b, n, w):
    """Property: guidance=0 -> pure uncond eps; any padding round-trips."""
    key = jax.random.PRNGKey(b * 100 + n)
    shape = (b, n, 3)
    z, eu, ec = (jax.random.normal(jax.random.fold_in(key, i), shape)
                 for i in range(3))
    out0 = fused_cfg_ddim_step(z, eu, ec, 0.0, 0.8, 0.6, 0.9, 0.436)
    ref0 = fused_cfg_ddim_step_ref(z, eu, ec, 0.0, 0.8, 0.6, 0.9, 0.436)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(ref0),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# group_mean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kn", [(1, 2), (4, 5), (8, 3)])
@pytest.mark.parametrize("feat", [(7,), (16, 24), (8, 8, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_mean_kernel(kn, feat, dtype):
    K, N = kn
    key = jax.random.PRNGKey(K * 10 + N)
    x = jax.random.normal(key, (K, N) + feat, dtype)
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (K, N)) > 0.3
            ).astype(jnp.float32)
    mask = mask.at[:, 0].set(1.0)           # at least one member
    out = masked_group_mean(x, mask)
    ref = masked_group_mean_ref(x, mask)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@given(st.integers(1, 6), st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_group_mean_full_mask_is_mean(k, n):
    x = jax.random.normal(jax.random.PRNGKey(k * 7 + n), (k, n, 33))
    out = masked_group_mean(x, jnp.ones((k, n)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x.mean(1)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [128, 256, 384])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_aligned(s, causal, dtype):
    B, H, D = 2, 4, 64
    key = jax.random.PRNGKey(s)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, s, H, D),
                                 dtype) for i in range(3))
    out = flash_attention(q, k, v, causal=causal)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, s, D),
        k.transpose(0, 2, 1, 3).reshape(B * H, s, D),
        v.transpose(0, 2, 1, 3).reshape(B * H, s, D),
        causal=causal, scale=1.0 / np.sqrt(D))
    ref = ref.reshape(B, H, s, D).transpose(0, 2, 1, 3)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("sq,sk", [(100, 100), (130, 260), (256, 100)])
def test_flash_attention_unaligned_and_cross(sq, sk):
    """Padding path + cross-attention (Sq != Sk, non-causal)."""
    B, H, D = 1, 2, 48
    key = jax.random.PRNGKey(sq * 1000 + sk)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, sq, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, sk, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, sk, H, D))
    out = flash_attention(q, k, v, causal=False)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, sq, D),
        k.transpose(0, 2, 1, 3).reshape(B * H, sk, D),
        v.transpose(0, 2, 1, 3).reshape(B * H, sk, D),
        causal=False, scale=1.0 / np.sqrt(D))
    ref = ref.reshape(B, H, sq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_gqa():
    B, S, H, Hkv, D = 2, 128, 8, 2, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    out = flash_attention(q, k, v, causal=True)
    kr = jnp.repeat(k, H // Hkv, axis=2)
    vr = jnp.repeat(v, H // Hkv, axis=2)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        kr.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        vr.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        causal=True, scale=1.0 / np.sqrt(D))
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
