"""Packed multi-group tick execution.

Covers: the per-row scalar-block kernel launches (ddim + dpmpp rows
variants vs the broadcast-scalar launches and per-element singles), the
pack/unpack round-trip, pack-signature bucketing rules, packed
shared/branch phase parity against per-group segment calls, and the
scheduler-level packed-vs-per-group streaming equivalence (results,
NFE, launch accounting) with and without the trunk cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.core import shared_sampling as ss
from repro.core.schedule import make_schedule
from repro.data.synthetic import ShapesDataset
from repro.kernels._tiles import (per_row_scalars, row_block, scalar_rows,
                                  tile_rows)
from repro.kernels.ddim_step.ops import fused_cfg_ddim_step
from repro.kernels.dpmpp_step.ops import fused_cfg_dpmpp_step
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving import packing
from repro.serving.scheduler import RequestScheduler
from repro.serving.trunk_cache import TrunkCache

SCHED = make_schedule(1000)
CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)
H = CFG.latent_size
SHAPE = (H, H, CFG.latent_channels)


def _eps_fn(z, t, c):
    return dit.forward(PARAMS, CFG, z, t, c)


NULL = jnp.zeros((CFG.cond_len, CFG.cond_dim))


# ---------------------------------------------------------------------------
# per-row kernel launches
# ---------------------------------------------------------------------------

def test_tile_rows_round_trip():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 7, 2))
    br = row_block(x[0].size, 256, 256)
    assert br % 8 == 0
    (t,), untile = tile_rows(br, 256, x)
    assert t.shape[0] == 3 and t.shape[2] == 256 and t.shape[1] % br == 0
    np.testing.assert_array_equal(np.asarray(untile(t)), np.asarray(x))


def test_scalar_rows_mixes_vectors_and_scalars():
    blk = scalar_rows((2.0, jnp.array([1.0, 2.0, 3.0]),
                       jnp.array([True, False, True])), 8, 3)
    assert blk.shape == (3, 8) and blk.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(blk[:, 0]), 2.0)
    np.testing.assert_array_equal(np.asarray(blk[:, 1]), [1.0, 2.0, 3.0])
    np.testing.assert_array_equal(np.asarray(blk[:, 2]), [1.0, 0.0, 1.0])
    np.testing.assert_array_equal(np.asarray(blk[:, 3:]), 0.0)
    assert per_row_scalars(2.0, jnp.array([1.0, 2.0]))
    assert not per_row_scalars(2.0, jnp.float32(3.0))


def _row_scalars(B, key):
    a_t = jax.random.uniform(key, (B,), minval=0.5, maxval=0.95)
    s_t = jnp.sqrt(1.0 - a_t ** 2)
    a_n = jnp.minimum(a_t + 0.04, 0.99)
    s_n = jnp.sqrt(1.0 - a_n ** 2)
    return a_t, s_t, a_n, s_n


def test_ddim_rows_kernel_matches_single_launches():
    """Per-row-scalar launch == one broadcast-scalar launch per element,
    bitwise (the packed path's kernel-level parity contract)."""
    B = 5
    k = jax.random.PRNGKey(7)
    z, eu, ec = (jax.random.normal(jax.random.fold_in(k, i), (B,) + SHAPE)
                 for i in range(3))
    a_t, s_t, a_n, s_n = _row_scalars(B, jax.random.fold_in(k, 9))
    rows = fused_cfg_ddim_step(z, eu, ec, 3.0, a_t, s_t, a_n, s_n,
                               interpret=True, clip_x0=1.5)
    for i in range(B):
        one = fused_cfg_ddim_step(
            z[i:i + 1], eu[i:i + 1], ec[i:i + 1], 3.0, float(a_t[i]),
            float(s_t[i]), float(a_n[i]), float(s_n[i]), interpret=True,
            clip_x0=1.5)
        np.testing.assert_array_equal(np.asarray(rows[i]), np.asarray(one[0]))


def test_dpmpp_rows_kernel_matches_single_launches():
    """Same contract for the 2M kernel — including rows whose warm-up
    flag differs (one group at its fork, others mid-phase)."""
    B = 4
    k = jax.random.PRNGKey(11)
    z, eu, ec, ep = (jax.random.normal(jax.random.fold_in(k, i),
                                       (B,) + SHAPE) for i in range(4))
    a_t, s_t, a_n, s_n = _row_scalars(B, jax.random.fold_in(k, 9))
    lam = jnp.log(a_t / s_t)
    lam_p = lam - 0.25
    lam_n = jnp.log(a_n / s_n)
    first = jnp.array([True, False, False, True])
    zr, er = fused_cfg_dpmpp_step(z, eu, ec, ep, 3.0, a_t, s_t, a_n, s_n,
                                  lam, lam_p, lam_n, first, clip_x0=1.5,
                                  interpret=True)
    for i in range(B):
        zo, eo = fused_cfg_dpmpp_step(
            z[i:i + 1], eu[i:i + 1], ec[i:i + 1], ep[i:i + 1], 3.0,
            float(a_t[i]), float(s_t[i]), float(a_n[i]), float(s_n[i]),
            float(lam[i]), float(lam_p[i]), float(lam_n[i]),
            bool(first[i]), clip_x0=1.5, interpret=True)
        np.testing.assert_array_equal(np.asarray(zr[i]), np.asarray(zo[0]))
        np.testing.assert_array_equal(np.asarray(er[i]), np.asarray(eo[0]))


# ---------------------------------------------------------------------------
# pack/unpack plumbing
# ---------------------------------------------------------------------------

class _FakeGroup:
    def __init__(self, n_members, steps_done, n_shared, beta, state,
                 key, width=None, shape=SHAPE, sampler="ddim",
                 total_steps=6):
        rows = 1 if state == "shared" else n_members
        self.members = list(range(n_members))
        self.steps_done = steps_done
        self.n_shared = n_shared
        self.beta = beta
        self.state = state
        self.shape = shape
        self.sampler = sampler
        self.total_steps = total_steps
        z = jax.random.normal(key, (rows,) + shape)
        self.carry = ss.SampleCarry(z, z * 0.5, jnp.int32(steps_done))
        self.cbar = jax.random.normal(key, (1, CFG.cond_len, CFG.cond_dim))
        self.cond_flat = jax.random.normal(
            key, (n_members, CFG.cond_len, CFG.cond_dim))
        self.mask = jnp.ones((1, n_members))


def test_pack_signature_and_build_packs():
    k = jax.random.PRNGKey(0)
    gs = [
        _FakeGroup(2, 0, 2, 0.3, "shared", k),   # 2 shared steps left
        _FakeGroup(3, 1, 2, 0.3, "shared", k),   # 1 shared step left
        _FakeGroup(1, 0, 2, 0.3, "shared", k),   # 2 left -> packs with [0]
        _FakeGroup(2, 2, 2, 0.3, "branch", k),   # branch
        _FakeGroup(2, 2, 3, 0.4, "branch", k),   # other beta bucket:
        #   beta is per-row data (step/fork idx), NOT a pack axis, so this
        #   packs with gs[3] — one launch across beta buckets
    ]
    packs = packing.build_packs(gs, slice_steps=4)
    keyed = {key: groups for key, groups in packs}
    assert len(packs) == 3
    assert keyed[packing.PackKey("shared", "ddim", SHAPE, 2)] \
        == [gs[0], gs[2]]
    assert keyed[packing.PackKey("shared", "ddim", SHAPE, 1)] == [gs[1]]
    assert keyed[packing.PackKey("branch", "ddim", SHAPE, 4)] \
        == [gs[3], gs[4]]
    # segment length is clamped by steps remaining in the phase
    assert packing.pack_signature(gs[1], 4).n_steps == 1


def test_build_packs_align_phases_one_bucket_per_phase():
    """The run_batch drain rule: aligning segment lengths to the minimum
    remaining within each phase collapses the signature space to one
    bucket per phase, and never drags a group past its phase boundary."""
    k = jax.random.PRNGKey(2)
    gs = [
        _FakeGroup(2, 0, 2, 0.3, "shared", k),   # 2 shared steps left
        _FakeGroup(3, 1, 2, 0.3, "shared", k),   # 1 left -> phase min = 1
        _FakeGroup(2, 2, 2, 0.3, "branch", k),   # 4 branch steps left
        _FakeGroup(2, 3, 3, 0.4, "branch", k),   # 3 left -> phase min = 3
    ]
    packs = packing.build_packs(gs, slice_steps=6, align_phases=True)
    keyed = {key: groups for key, groups in packs}
    assert len(packs) == 2
    assert keyed[packing.PackKey("shared", "ddim", SHAPE, 1)] \
        == [gs[0], gs[1]]
    assert keyed[packing.PackKey("branch", "ddim", SHAPE, 3)] \
        == [gs[2], gs[3]]
    # slice_steps still caps the aligned length
    capped = packing.build_packs(gs, slice_steps=2, align_phases=True)
    assert {key.n_steps for key, _ in capped} == {1, 2}


def test_pack_unpack_round_trip_preserves_rows():
    k = jax.random.PRNGKey(1)
    shared = [_FakeGroup(2, 1, 3, 0.3, "shared", jax.random.fold_in(k, 0)),
              _FakeGroup(1, 2, 3, 0.3, "shared", jax.random.fold_in(k, 1))]
    carry, cbar = packing.pack_shared(shared)
    assert carry.z.shape == (2,) + SHAPE and cbar.shape[0] == 2
    np.testing.assert_array_equal(np.asarray(carry.step_idx), [1, 2])
    before = [np.asarray(g.carry.z) for g in shared]
    packing.unpack_shared(carry, shared)
    for g, b in zip(shared, before):
        np.testing.assert_array_equal(np.asarray(g.carry.z), b)

    branch = [_FakeGroup(2, 3, 3, 0.3, "branch", jax.random.fold_in(k, 2)),
              _FakeGroup(3, 4, 2, 0.3, "branch", jax.random.fold_in(k, 3))]
    width = 3
    carry, cond, mask, fork = packing.pack_branch(branch, width)
    assert carry.z.shape == (2 * width,) + SHAPE
    assert cond.shape[0] == 2 * width
    np.testing.assert_array_equal(np.asarray(mask), [[1, 1, 0], [1, 1, 1]])
    np.testing.assert_array_equal(np.asarray(fork), [3, 3, 3, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(carry.step_idx),
                                  [3, 3, 3, 4, 4, 4])
    # pad rows replicate member 0
    np.testing.assert_array_equal(np.asarray(carry.z[2]),
                                  np.asarray(carry.z[0]))
    before = [np.asarray(g.carry.z) for g in branch]
    packing.unpack_branch(carry, branch, width)
    for g, b in zip(branch, before):
        assert g.carry.z.shape[0] == len(g.members)
        np.testing.assert_array_equal(np.asarray(g.carry.z), b)
    assert packing.pad_stats(branch, width) == (6, 1)


# ---------------------------------------------------------------------------
# packed phase calls == per-group phase calls (segment-level parity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler,step_impl",
                         [("ddim", "reference"), ("dpmpp", "fused")])
def test_packed_phases_match_per_group(sampler, step_impl):
    """Stacked carries with per-row step/fork indices reproduce the
    per-group segment results bitwise — groups at different grid offsets,
    different widths (padded) and different fork points in one call."""
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=3.0,
                      sampler=sampler, step_impl=step_impl)
    k = jax.random.PRNGKey(5)
    cbarA = jax.random.normal(jax.random.fold_in(k, 0),
                              (1, CFG.cond_len, CFG.cond_dim))
    cbarB = jax.random.normal(jax.random.fold_in(k, 1),
                              (1, CFG.cond_len, CFG.cond_dim))
    # --- shared phase: A two steps in, B at the start ------------------
    cA = ss.shared_phase(_eps_fn, SCHED, sage,
                         ss.init_carry(jax.random.fold_in(k, 2), 1, SHAPE),
                         cbarA, NULL, 2)
    cB = ss.init_carry(jax.random.fold_in(k, 3), 1, SHAPE)
    a_ref = ss.shared_phase(_eps_fn, SCHED, sage, cA, cbarA, NULL, 2)
    b_ref = ss.shared_phase(_eps_fn, SCHED, sage, cB, cbarB, NULL, 2)
    packed = ss.SampleCarry(jnp.concatenate([cA.z, cB.z], 0),
                            jnp.concatenate([cA.eps_prev, cB.eps_prev], 0),
                            jnp.array([2, 0], jnp.int32))
    out = ss.shared_phase(_eps_fn, SCHED, sage, packed,
                          jnp.concatenate([cbarA, cbarB], 0), NULL, 2)
    np.testing.assert_array_equal(np.asarray(out.z[:1]), np.asarray(a_ref.z))
    np.testing.assert_array_equal(np.asarray(out.z[1:]), np.asarray(b_ref.z))
    np.testing.assert_array_equal(np.asarray(out.eps_prev[:1]),
                                  np.asarray(a_ref.eps_prev))

    # --- branch phase: A (2 members, forked @2, one step in), B (3
    # members, at its fork @3) — packed to width 3 with a masked pad row
    condA = jax.random.normal(jax.random.fold_in(k, 6),
                              (2, CFG.cond_len, CFG.cond_dim))
    condB = jax.random.normal(jax.random.fold_in(k, 7),
                              (3, CFG.cond_len, CFG.cond_dim))
    fA = ss.fork_carry(cA, 2)              # A forked at global step 2
    maskA = jnp.ones((1, 2))
    fA = ss.branch_phase(_eps_fn, SCHED, sage, fA, condA, maskA, NULL, 1,
                         fork_idx=2)
    cB3 = ss.shared_phase(_eps_fn, SCHED, sage, b_ref, cbarB, NULL, 1)
    fB = ss.fork_carry(cB3, 3)
    maskB = jnp.ones((1, 3))
    a2 = ss.branch_phase(_eps_fn, SCHED, sage, fA, condA, maskA, NULL, 2,
                         fork_idx=2)
    b2 = ss.branch_phase(_eps_fn, SCHED, sage, fB, condB, maskB, NULL, 2,
                         fork_idx=3)

    def pad(x):
        return jnp.concatenate([x, x[:1]], 0)

    packed = ss.SampleCarry(
        jnp.concatenate([pad(fA.z), fB.z], 0),
        jnp.concatenate([pad(fA.eps_prev), fB.eps_prev], 0),
        jnp.array([3, 3, 3, 3, 3, 3], jnp.int32))
    out = ss.branch_phase(
        _eps_fn, SCHED, sage, packed,
        jnp.concatenate([pad(condA), condB], 0),
        jnp.array([[1.0, 1.0, 0.0], [1.0, 1.0, 1.0]]), NULL, 2,
        fork_idx=jnp.array([2, 2, 2, 3, 3, 3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out.z[:2]), np.asarray(a2.z))
    np.testing.assert_array_equal(np.asarray(out.z[3:]), np.asarray(b2.z))
    np.testing.assert_array_equal(np.asarray(out.eps_prev[3:]),
                                  np.asarray(b2.eps_prev))


# ---------------------------------------------------------------------------
# scheduler-level equivalence
# ---------------------------------------------------------------------------

def _stream(packed, cache=None, sampler="ddim", step_impl="reference"):
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=2.0,
                      tau_min=0.2, sampler=sampler, step_impl=step_impl)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=3, slice_steps=2, max_wait_ticks=1,
                             packed=packed, trunk_cache=cache)
    _, prompts = ShapesDataset(res=16).batch(0, 6)
    done, t = [], 0.0
    for _ in range(2):
        sched.submit(prompts, now=t)
        while sched.pending:
            t += 1.0
            done.extend(sched.tick(now=t))
    return sched, done


def test_scheduler_packed_matches_per_group():
    """The packed tick loop must be invisible: same completions in the
    same order, bitwise-identical images, identical NFE — with strictly
    fewer launches."""
    sp, dp = _stream(packed=True)
    sg, dg = _stream(packed=False)
    assert [c.prompt for c in dp] == [c.prompt for c in dg]
    for a, b in zip(dp, dg):
        assert a.image.dtype == b.image.dtype
        np.testing.assert_array_equal(a.image, b.image)
    assert sp.stats["nfe"] == sg.stats["nfe"]
    assert sp.stats["launches"] < sg.stats["launches"]
    s = sp.summary()
    assert s["launches_per_tick"] < sg.summary()["launches_per_tick"]
    assert 0.0 <= s["pad_waste"] < 1.0


def test_scheduler_packed_with_trunk_cache_interleaves():
    """Cache fills/hits must interleave identically with packed groups:
    same hit pattern, same outputs, same NFE savings as per-group."""
    sp, dp = _stream(packed=True, cache=TrunkCache(tau_trunk=0.9))
    sg, dg = _stream(packed=False, cache=TrunkCache(tau_trunk=0.9))
    assert sp.trunk_cache.stats["hits"] == sg.trunk_cache.stats["hits"] > 0
    assert sp.stats["nfe_saved_cache"] == sg.stats["nfe_saved_cache"] > 0
    assert [c.cache_hit for c in dp] == [c.cache_hit for c in dg]
    for a, b in zip(dp, dg):
        np.testing.assert_array_equal(a.image, b.image)
    assert sp.stats["launches"] < sg.stats["launches"]


def test_scheduler_packed_cache_parity_under_eviction_pressure():
    """Trunk stores run in todo order (not pack-bucket order), so the
    cache's insert/LRU sequence — and therefore WHICH entry a byte
    budget evicts — must match per-group mode exactly.  A one-entry
    budget makes any ordering divergence flip a later hit/miss."""
    one_entry = 2 * 4 * int(np.prod((1,) + SHAPE))    # z + eps_prev bytes
    sp, dp = _stream(packed=True,
                     cache=TrunkCache(tau_trunk=0.9, max_bytes=one_entry))
    sg, dg = _stream(packed=False,
                     cache=TrunkCache(tau_trunk=0.9, max_bytes=one_entry))
    assert sp.trunk_cache.stats == sg.trunk_cache.stats
    assert sp.stats["nfe"] == sg.stats["nfe"]
    assert [c.cache_hit for c in dp] == [c.cache_hit for c in dg]
    for a, b in zip(dp, dg):
        np.testing.assert_array_equal(a.image, b.image)
