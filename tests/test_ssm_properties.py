"""Property tests for the recurrent mixers: the chunked SSD scan must agree
with a direct sequential recurrence for any (chunk, length) split, and the
RG-LRU associative scan with its step form."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.config import get_config
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.ssm import ssd_chunked


def ssd_sequential(x, dA, B_, C_):
    """Direct recurrence oracle: h_t = exp(dA_t) h_{t-1} + B_t x_t."""
    b, l, h, p = x.shape
    n = B_.shape[-1]
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        state = (state * jnp.exp(dA[:, t]).reshape(b, h, 1, 1)
                 + jnp.einsum("bhp,bn->bhpn", x[:, t], B_[:, t]))
        ys.append(jnp.einsum("bhpn,bn->bhp", state, C_[:, t]))
    return jnp.stack(ys, axis=1), state


@given(st.integers(1, 3), st.integers(2, 24), st.sampled_from([2, 4, 8]))
@settings(max_examples=12, deadline=None)
def test_ssd_chunked_matches_sequential(b, l, chunk):
    key = jax.random.PRNGKey(b * 1000 + l * 10 + chunk)
    h, p, n = 2, 4, 8
    x = jax.random.normal(jax.random.fold_in(key, 0), (b, l, h, p))
    dA = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                            (b, l, h)))
    B_ = jax.random.normal(jax.random.fold_in(key, 2), (b, l, n))
    C_ = jax.random.normal(jax.random.fold_in(key, 3), (b, l, n))
    y_ref, s_ref = ssd_sequential(x, dA, B_, C_)
    y, s = ssd_chunked(x, dA, B_, C_, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_prefill_state_feeds_decode():
    """ssm_full(return_cache) + ssm_decode == ssm_full over the longer seq."""
    cfg = get_config("mamba2-780m", smoke=True)
    p = ssm_lib.init_ssm(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    full = ssm_lib.ssm_full(p, cfg, u)
    out8, cache = ssm_lib.ssm_full(p, cfg, u[:, :8], return_cache=True)
    out9, _ = ssm_lib.ssm_decode(p, cfg, u[:, 8:9], cache)
    np.testing.assert_allclose(np.asarray(out9[:, 0], np.float32),
                               np.asarray(full[:, 8], np.float32),
                               rtol=2e-2, atol=2e-2)


@given(st.integers(2, 16))
@settings(max_examples=8, deadline=None)
def test_rglru_scan_matches_step(l):
    cfg = get_config("recurrentgemma-2b", smoke=True)
    p = rglru_lib.init_rglru(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(l), (1, l, cfg.d_model))
    full = rglru_lib.rglru_full(p, cfg, u)
    cache = rglru_lib.rglru_cache_init(cfg, 1, u.dtype)
    outs = []
    for t in range(l):
        o, cache = rglru_lib.rglru_decode(p, cfg, u[:, t:t + 1], cache)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)
