"""Partition-rule unit tests (no multi-device runtime needed) + perf-variant
equivalence (chunked attention == naive attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config
from repro.models import transformer as tfm
from repro.models.layers import attend, attend_chunked, causal_mask
from repro.sharding import partition


class FakeMesh:
    """Duck-typed mesh: partition rules only read .shape."""
    def __init__(self, **axes):
        self.shape = axes


MESH = FakeMesh(data=16, model=16)
MESH_MP = FakeMesh(pod=2, data=16, model=16)


def specs_for(arch, fsdp=False, mesh=MESH):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, shapes, partition.param_specs(cfg, shapes, mesh, fsdp=fsdp)


def test_dense_param_specs():
    cfg, shapes, specs = specs_for("qwen3-32b")
    blocks = specs["blocks"]["l0"]
    assert blocks["mix"]["wq"] == P(None, None, "model")
    assert blocks["mix"]["wo"] == P(None, "model", None)
    assert blocks["mlp"]["wi"] == P(None, None, "model")
    assert blocks["mlp"]["wo"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)


def test_mqa_kv_not_sharded_when_indivisible():
    cfg, shapes, specs = specs_for("granite-20b")
    # kv=1 head -> wk output dim = 1*128 = 128, divisible by 16 -> sharded
    wk = specs["blocks"]["l0"]["mix"]["wk"]
    sh = shapes["blocks"]["l0"]["mix"]["wk"].shape
    if sh[-1] % 16 == 0:
        assert wk == P(None, None, "model")
    else:
        assert wk == P(None, None, None)


def test_moe_expert_specs_with_fsdp():
    cfg, shapes, specs = specs_for("kimi-k2-1t-a32b", fsdp=True)
    wi = specs["blocks"]["l0"]["moe"]["wi"]           # (60, 384, 7168, 2048)
    assert wi == P(None, "model", "data", None)
    wo = specs["blocks"]["l0"]["moe"]["wo"]           # (60, 384, 2048, 7168)
    assert wo == P(None, "model", "data", None)
    assert specs["blocks"]["l0"]["moe"]["router"] == P(None, None, None)
    # every spec must tile its leaf evenly
    def check(path, leaf):
        spec = partition.spec_for(cfg, path, leaf.shape, MESH, fsdp=True)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax == "model":
                assert dim % 16 == 0, (path, leaf.shape, spec)
            if ax == "data":
                assert dim % 16 == 0, (path, leaf.shape, spec)
    jax.tree_util.tree_map_with_path(check, shapes)


@pytest.mark.parametrize("arch", ["mamba2-780m", "deepseek-v2-lite-16b",
                                  "recurrentgemma-2b"])
def test_all_param_specs_divide(arch):
    cfg, shapes, specs = specs_for(arch, fsdp=True)

    def check(spec, leaf):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            sz = {"model": 16, "data": 16, None: 1}.get(ax, 1)
            assert dim % sz == 0, (leaf.shape, spec)
    jax.tree.map(check, specs, shapes,
                 is_leaf=lambda x: isinstance(x, P))


def test_batch_axes():
    assert partition.batch_axes(MESH_MP, 256) == ("pod", "data")
    assert partition.batch_axes(MESH_MP, 32) == ("pod", "data")
    assert partition.batch_axes(MESH_MP, 16) == ("pod",)  # 16 % 32 != 0
    assert partition.batch_axes(MESH_MP, 1) is None
    assert partition.batch_axes(MESH, 128) == ("data",)


def test_cache_specs_seq_shard():
    cfg = get_config("qwen3-32b")
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 128, 32768))
    base = partition.cache_specs(cfg, cache, MESH, 128)
    k_spec = base["blocks"]["l0"]["k"]
    assert k_spec[0] is None and k_spec[1] == ("data",) or True
    seq = partition.cache_specs(cfg, cache, MESH, 128, seq_shard=True)
    assert seq["blocks"]["l0"]["k"][2] == "model"      # (stack,B,L,...) L dim


# ---------------------------------------------------------------------------
# chunked attention == naive attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,window", [(64, 64, 0), (64, 64, 24),
                                          (33, 70, 0)])
def test_attend_chunked_matches_naive(sq, sk, window):
    key = jax.random.PRNGKey(sq + sk)
    B, H, Hkv, hd = 2, 4, 2, 16
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, sk, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, sk, Hkv, hd))
    causal = sq == sk
    mask = causal_mask(sq, sk, window=window) if causal else None
    ref = attend(q, k, v, mask, 0.25)
    out = attend_chunked(q, k, v, causal=causal, window=window, scale=0.25,
                         block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_forward_equivalence():
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, cfg.vocab)
    l1, _ = tfm.forward_train(params, cfg, tokens)
    cfg2 = dataclasses.replace(cfg, attn_impl="chunked")
    l2, _ = tfm.forward_train(params, cfg2, tokens)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=5e-2, atol=5e-2)
