"""Property-based grouping invariants (the optional-hypothesis path).

``tests/hypothesis_compat.py`` keeps collection clean without hypothesis
installed: the ``@given`` cases below then skip, while the deterministic
twins (same checker functions, fixed seeds) always run — so the
invariants are exercised everywhere and *fuzzed* where hypothesis is
available (CI's tier1 job installs it).

Invariants under test:

* ``grouping.incremental_assign`` — arrival-order admission yields a
  partition whose every group is a clique of the (tau_min, tau_max]
  threshold graph, within the size cap, regardless of embedding
  distribution or arrival order;
* ``grouping.greedy_clique_groups`` — batch grouping satisfies the same
  pairwise invariant;
* ``grouping.flatten_groups`` — row splitting round-trips: members are
  preserved in order, rows respect the width, and the row layout matches
  ``pad_groups``'s packing exactly.
"""
import numpy as np

from repro.core import grouping

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st


# ---------------------------------------------------------------------------
# checkers (shared by the property cases and the deterministic twins)
# ---------------------------------------------------------------------------

def check_incremental_clique(embeds: np.ndarray, order, tau: float,
                             gmax: int) -> None:
    """Feed ``embeds`` in ``order`` through incremental_assign and verify
    the partition + pairwise-clique + size invariants."""
    groups = []                              # member-index lists, arrival
    for i in order:
        gi = grouping.incremental_assign(
            embeds[i], [embeds[g] for g in groups], tau, group_max=gmax)
        if gi >= 0:
            groups[gi].append(i)
        else:
            groups.append([i])
    assert sorted(i for g in groups for i in g) == sorted(order)
    sim = grouping.similarity_matrix(embeds)
    for g in groups:
        assert 1 <= len(g) <= gmax
        for a in g:
            for b in g:
                if a != b:
                    assert grouping.edge_mask(
                        np.asarray(sim[a, b]), tau).all(), (a, b, sim[a, b])


def check_greedy_clique(embeds: np.ndarray, tau: float, gmax: int) -> None:
    sim = grouping.similarity_matrix(embeds)
    groups = grouping.greedy_clique_groups(sim, tau, group_max=gmax)
    assert sorted(i for g in groups for i in g) == list(range(len(embeds)))
    for g in groups:
        assert 1 <= len(g) <= gmax
        for a in g:
            for b in g:
                if a != b:
                    assert grouping.edge_mask(
                        np.asarray(sim[a, b]), tau).all(), (a, b, sim[a, b])


def check_flatten_round_trip(groups, width: int) -> None:
    flat = grouping.flatten_groups(groups, width)
    # round-trip: concatenating the rows reproduces the unsplit members
    # in order, nothing lost or duplicated
    assert [m for row in flat for m in row] == [m for g in groups
                                                for m in g]
    assert all(1 <= len(row) <= width for row in flat)
    # and the rows are exactly pad_groups's packing layout
    idx, mask = grouping.pad_groups(groups, width)
    assert idx.shape == (len(flat), width)
    for k, row in enumerate(flat):
        assert idx[k, :len(row)].tolist() == row
        assert idx[k, len(row):].tolist() == [row[0]] * (width - len(row))
        assert mask[k].sum() == len(row)


def _embeds_and_order(n: int, d: int, seed: int, clustered: bool):
    rng = np.random.RandomState(seed)
    if clustered:
        # a few tight clusters — exercises full groups and the size cap
        centers = rng.randn(max(1, n // 3), d)
        e = (centers[rng.randint(len(centers), size=n)]
             + 0.05 * rng.randn(n, d))
    else:
        e = rng.randn(n, d)
    return np.asarray(e, np.float32), rng.permutation(n).tolist()


# ---------------------------------------------------------------------------
# property cases (skip without hypothesis)
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 24), d=st.integers(2, 8),
       seed=st.integers(0, 2 ** 31 - 1),
       tau=st.floats(-0.9, 0.95), gmax=st.integers(1, 6),
       clustered=st.booleans())
@settings(max_examples=40, deadline=None)
def test_incremental_assign_clique_property(n, d, seed, tau, gmax,
                                            clustered):
    embeds, order = _embeds_and_order(n, d, seed, clustered)
    check_incremental_clique(embeds, order, tau, gmax)


@given(n=st.integers(1, 24), d=st.integers(2, 8),
       seed=st.integers(0, 2 ** 31 - 1),
       tau=st.floats(-0.9, 0.95), gmax=st.integers(1, 6),
       clustered=st.booleans())
@settings(max_examples=40, deadline=None)
def test_greedy_clique_property(n, d, seed, tau, gmax, clustered):
    embeds, _ = _embeds_and_order(n, d, seed, clustered)
    check_greedy_clique(embeds, tau, gmax)


@given(sizes=st.lists(st.integers(1, 9), min_size=0, max_size=8),
       width=st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_flatten_groups_round_trip_property(sizes, width):
    start, groups = 0, []
    for s in sizes:
        groups.append(list(range(start, start + s)))
        start += s
    check_flatten_round_trip(groups, width)


# ---------------------------------------------------------------------------
# deterministic twins (always run — including without hypothesis)
# ---------------------------------------------------------------------------

def test_incremental_assign_clique_deterministic():
    for seed, clustered in ((0, False), (1, True), (2, True)):
        embeds, order = _embeds_and_order(20, 6, seed, clustered)
        check_incremental_clique(embeds, order, tau=0.3, gmax=4)
    # degenerate sizes
    embeds, order = _embeds_and_order(1, 2, 3, False)
    check_incremental_clique(embeds, order, tau=0.0, gmax=1)


def test_greedy_clique_deterministic():
    for seed, clustered in ((0, False), (1, True)):
        embeds, _ = _embeds_and_order(18, 5, seed, clustered)
        check_greedy_clique(embeds, tau=0.2, gmax=5)


def test_flatten_groups_round_trip_deterministic():
    check_flatten_round_trip([[0, 1, 2, 3, 4, 5, 6], [7, 8], [9]], 4)
    check_flatten_round_trip([], 3)
    check_flatten_round_trip([[0]], 1)


def test_hypothesis_path_active_when_installed():
    """Documents which mode this environment runs the suite in (and makes
    the optional dependency's state visible in -v output)."""
    assert HAVE_HYPOTHESIS in (True, False)


# ---------------------------------------------------------------------------
# hetero packing invariants (shapes / samplers / per-row step budgets)
# ---------------------------------------------------------------------------

class _PackGroup:
    """Duck-typed group for packing invariants (no model, no scheduler)."""

    _gid = 0

    def __init__(self, state, steps_done, n_shared, total_steps, shape,
                 sampler, n_members, with_carry=False):
        self.state = state
        self.steps_done = steps_done
        self.n_shared = n_shared
        self.total_steps = total_steps
        self.shape = tuple(shape)
        self.sampler = sampler
        self.members = list(range(n_members))
        self.beta = 0.25
        _PackGroup._gid += 1
        self.gid = _PackGroup._gid
        if with_carry:
            import jax.numpy as jnp
            rng = np.random.RandomState(self.gid)
            z = jnp.asarray(rng.randn(n_members, *self.shape)
                            .astype(np.float32))
            from repro.core.shared_sampling import SampleCarry
            self.carry = SampleCarry(z, z * 0.5, jnp.int32(steps_done))
            self.cond_flat = jnp.asarray(
                rng.randn(n_members, 3, 4).astype(np.float32))


def _mk_groups(specs, with_carry=False):
    """specs: list of (state_bit, steps_done_frac, total_steps, shape_i,
    sampler_bit, n_members) drawn by hypothesis; derive a consistent
    group (steps_done inside the right phase range)."""
    shapes = [(8, 8, 4), (4, 4, 4), (4, 8, 4)]
    out = []
    for st_bit, frac, total, shape_i, smp_bit, n in specs:
        n_shared = max(0, total // 3)
        state = "shared" if (st_bit and n_shared > 0) else "branch"
        if state == "shared":
            done = int(frac * max(0, n_shared - 1))          # < n_shared
        else:
            done = n_shared + int(frac * max(0, total - n_shared - 1))
        out.append(_PackGroup(state, done, n_shared, total,
                              shapes[shape_i % 3],
                              ("ddim", "dpmpp")[smp_bit % 2], n,
                              with_carry=with_carry))
    return out


def check_packs_never_mix(groups, slice_steps, mix_samplers,
                          align_phases) -> None:
    from repro.serving import packing
    packs = packing.build_packs(groups, slice_steps,
                                mix_samplers=mix_samplers,
                                align_phases=align_phases)
    seen = [g for _, gs in packs for g in gs]
    assert sorted(id(g) for g in seen) == sorted(id(g) for g in groups)
    for key, gs in packs:
        # a bucket NEVER mixes shapes, and the key names the bucket shape
        assert {g.shape for g in gs} == {key.shape}
        assert {g.state for g in gs} == {key.phase}
        if mix_samplers:
            assert key.sampler == packing.MIXED
        else:
            # unmixed: one solver per bucket, named by the key
            assert {g.sampler for g in gs} == {key.sampler}
        for g in gs:
            # no group is dragged past its phase boundary or held at 0
            assert 1 <= key.n_steps <= packing.phase_remaining(g)


def check_grid_rows_and_nfe(groups, sched_T, slice_steps) -> None:
    """pack_grid row fidelity + exact step-budget conservation under a
    simulated segment drain (the per-row machinery never over- or
    under-steps a tier budget)."""
    from repro.core.schedule import ddim_timesteps
    from repro.serving import packing
    grid = np.asarray(packing.pack_grid(groups, sched_T))
    ts = [g.total_steps for g in groups]
    if len(set(ts)) == 1:
        np.testing.assert_array_equal(
            grid, ddim_timesteps(sched_T, ts[0]))
    else:
        assert grid.shape == (len(groups), max(ts) + 1)
        for j, g in enumerate(groups):
            own = ddim_timesteps(sched_T, g.total_steps)
            np.testing.assert_array_equal(grid[j, :len(own)], own)
            np.testing.assert_array_equal(grid[j, len(own):], 0)
    # simulated drain: advance per-group min(slice, phase_remaining)
    for g in groups:
        stepped = 0
        guard = 0
        while g.steps_done < g.total_steps:
            s = min(slice_steps, packing.phase_remaining(g))
            assert s >= 1
            g.steps_done += s
            stepped += s
            if g.state == "shared" and g.steps_done == g.n_shared:
                g.state = "branch"
            guard += 1
            assert guard <= 2 * g.total_steps
        assert g.steps_done == g.total_steps      # exact: never overshoots


def check_branch_pack_round_trip(groups, width) -> None:
    from repro.serving import packing
    before = [np.asarray(g.carry.z) for g in groups]
    carry, cond, mask, fork = packing.pack_branch(groups, width)
    assert carry.z.shape[0] == len(groups) * width
    rows, pads = packing.pad_stats(groups, width)
    assert rows == len(groups) * width
    assert pads == sum(width - len(g.members) for g in groups)
    np.testing.assert_array_equal(
        np.asarray(mask).sum(axis=1), [len(g.members) for g in groups])
    for j, g in enumerate(groups):
        lo = j * width
        # pad rows replicate member 0 (mask-0, never reduced)
        for p in range(len(g.members), width):
            np.testing.assert_array_equal(np.asarray(carry.z[lo + p]),
                                          before[j][0])
        np.testing.assert_array_equal(
            np.asarray(carry.step_idx[lo:lo + width]), g.steps_done)
        np.testing.assert_array_equal(
            np.asarray(fork[lo:lo + width]), g.n_shared)
    packing.unpack_branch(carry, groups, width)
    for g, b in zip(groups, before):
        assert g.carry.z.shape[0] == len(g.members)
        np.testing.assert_array_equal(np.asarray(g.carry.z), b)


_SPEC = st.tuples(st.booleans(), st.floats(0.0, 1.0), st.integers(2, 12),
                  st.integers(0, 2), st.integers(0, 1), st.integers(1, 4))


@given(specs=st.lists(_SPEC, min_size=1, max_size=10),
       slice_steps=st.integers(1, 6), mix=st.booleans(),
       align=st.booleans())
@settings(max_examples=60, deadline=None)
def test_build_packs_never_mixes_shapes_property(specs, slice_steps, mix,
                                                 align):
    check_packs_never_mix(_mk_groups(specs), slice_steps, mix, align)


@given(specs=st.lists(_SPEC, min_size=1, max_size=8),
       sched_T=st.integers(50, 1000), slice_steps=st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_pack_grid_and_nfe_conservation_property(specs, sched_T,
                                                 slice_steps):
    check_grid_rows_and_nfe(_mk_groups(specs), sched_T, slice_steps)


@given(specs=st.lists(_SPEC, min_size=1, max_size=4),
       extra=st.integers(0, 2))
@settings(max_examples=20, deadline=None)
def test_branch_pack_round_trip_property(specs, extra):
    # one shape per pack (build_packs guarantees it) — pin shape_i
    specs = [(False, f, t, 1, s, n) for (_, f, t, _, s, n) in specs]
    groups = _mk_groups(specs, with_carry=True)
    width = max(len(g.members) for g in groups) + extra
    check_branch_pack_round_trip(groups, width)


def test_build_packs_never_mixes_shapes_deterministic():
    rng = np.random.RandomState(7)
    specs = [(bool(rng.randint(2)), float(rng.rand()),
              int(rng.randint(2, 12)), int(rng.randint(3)),
              int(rng.randint(2)), int(rng.randint(1, 5)))
             for _ in range(12)]
    for mix in (False, True):
        for align in (False, True):
            check_packs_never_mix(_mk_groups(specs), 3, mix, align)


def test_pack_grid_and_nfe_conservation_deterministic():
    rng = np.random.RandomState(11)
    for _ in range(4):
        specs = [(bool(rng.randint(2)), float(rng.rand()),
                  int(rng.randint(2, 12)), int(rng.randint(3)),
                  int(rng.randint(2)), int(rng.randint(1, 5)))
                 for _ in range(6)]
        check_grid_rows_and_nfe(_mk_groups(specs), 1000,
                                int(rng.randint(1, 6)))
    # uniform budgets -> the 1-D fast-path grid
    uni = [(False, 0.5, 6, 0, 0, 2), (True, 0.0, 6, 1, 1, 3)]
    check_grid_rows_and_nfe(_mk_groups(uni), 100, 2)


def test_branch_pack_round_trip_deterministic():
    specs = [(False, 0.3, 8, 1, 0, 1), (False, 0.9, 4, 1, 1, 3),
             (False, 0.0, 6, 1, 0, 2)]
    groups = _mk_groups(specs, with_carry=True)
    check_branch_pack_round_trip(groups, 3)
