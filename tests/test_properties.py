"""Property-based grouping invariants (the optional-hypothesis path).

``tests/hypothesis_compat.py`` keeps collection clean without hypothesis
installed: the ``@given`` cases below then skip, while the deterministic
twins (same checker functions, fixed seeds) always run — so the
invariants are exercised everywhere and *fuzzed* where hypothesis is
available (CI's tier1 job installs it).

Invariants under test:

* ``grouping.incremental_assign`` — arrival-order admission yields a
  partition whose every group is a clique of the (tau_min, tau_max]
  threshold graph, within the size cap, regardless of embedding
  distribution or arrival order;
* ``grouping.greedy_clique_groups`` — batch grouping satisfies the same
  pairwise invariant;
* ``grouping.flatten_groups`` — row splitting round-trips: members are
  preserved in order, rows respect the width, and the row layout matches
  ``pad_groups``'s packing exactly.
"""
import numpy as np

from repro.core import grouping

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st


# ---------------------------------------------------------------------------
# checkers (shared by the property cases and the deterministic twins)
# ---------------------------------------------------------------------------

def check_incremental_clique(embeds: np.ndarray, order, tau: float,
                             gmax: int) -> None:
    """Feed ``embeds`` in ``order`` through incremental_assign and verify
    the partition + pairwise-clique + size invariants."""
    groups = []                              # member-index lists, arrival
    for i in order:
        gi = grouping.incremental_assign(
            embeds[i], [embeds[g] for g in groups], tau, group_max=gmax)
        if gi >= 0:
            groups[gi].append(i)
        else:
            groups.append([i])
    assert sorted(i for g in groups for i in g) == sorted(order)
    sim = grouping.similarity_matrix(embeds)
    for g in groups:
        assert 1 <= len(g) <= gmax
        for a in g:
            for b in g:
                if a != b:
                    assert grouping.edge_mask(
                        np.asarray(sim[a, b]), tau).all(), (a, b, sim[a, b])


def check_greedy_clique(embeds: np.ndarray, tau: float, gmax: int) -> None:
    sim = grouping.similarity_matrix(embeds)
    groups = grouping.greedy_clique_groups(sim, tau, group_max=gmax)
    assert sorted(i for g in groups for i in g) == list(range(len(embeds)))
    for g in groups:
        assert 1 <= len(g) <= gmax
        for a in g:
            for b in g:
                if a != b:
                    assert grouping.edge_mask(
                        np.asarray(sim[a, b]), tau).all(), (a, b, sim[a, b])


def check_flatten_round_trip(groups, width: int) -> None:
    flat = grouping.flatten_groups(groups, width)
    # round-trip: concatenating the rows reproduces the unsplit members
    # in order, nothing lost or duplicated
    assert [m for row in flat for m in row] == [m for g in groups
                                                for m in g]
    assert all(1 <= len(row) <= width for row in flat)
    # and the rows are exactly pad_groups's packing layout
    idx, mask = grouping.pad_groups(groups, width)
    assert idx.shape == (len(flat), width)
    for k, row in enumerate(flat):
        assert idx[k, :len(row)].tolist() == row
        assert idx[k, len(row):].tolist() == [row[0]] * (width - len(row))
        assert mask[k].sum() == len(row)


def _embeds_and_order(n: int, d: int, seed: int, clustered: bool):
    rng = np.random.RandomState(seed)
    if clustered:
        # a few tight clusters — exercises full groups and the size cap
        centers = rng.randn(max(1, n // 3), d)
        e = (centers[rng.randint(len(centers), size=n)]
             + 0.05 * rng.randn(n, d))
    else:
        e = rng.randn(n, d)
    return np.asarray(e, np.float32), rng.permutation(n).tolist()


# ---------------------------------------------------------------------------
# property cases (skip without hypothesis)
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 24), d=st.integers(2, 8),
       seed=st.integers(0, 2 ** 31 - 1),
       tau=st.floats(-0.9, 0.95), gmax=st.integers(1, 6),
       clustered=st.booleans())
@settings(max_examples=40, deadline=None)
def test_incremental_assign_clique_property(n, d, seed, tau, gmax,
                                            clustered):
    embeds, order = _embeds_and_order(n, d, seed, clustered)
    check_incremental_clique(embeds, order, tau, gmax)


@given(n=st.integers(1, 24), d=st.integers(2, 8),
       seed=st.integers(0, 2 ** 31 - 1),
       tau=st.floats(-0.9, 0.95), gmax=st.integers(1, 6),
       clustered=st.booleans())
@settings(max_examples=40, deadline=None)
def test_greedy_clique_property(n, d, seed, tau, gmax, clustered):
    embeds, _ = _embeds_and_order(n, d, seed, clustered)
    check_greedy_clique(embeds, tau, gmax)


@given(sizes=st.lists(st.integers(1, 9), min_size=0, max_size=8),
       width=st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_flatten_groups_round_trip_property(sizes, width):
    start, groups = 0, []
    for s in sizes:
        groups.append(list(range(start, start + s)))
        start += s
    check_flatten_round_trip(groups, width)


# ---------------------------------------------------------------------------
# deterministic twins (always run — including without hypothesis)
# ---------------------------------------------------------------------------

def test_incremental_assign_clique_deterministic():
    for seed, clustered in ((0, False), (1, True), (2, True)):
        embeds, order = _embeds_and_order(20, 6, seed, clustered)
        check_incremental_clique(embeds, order, tau=0.3, gmax=4)
    # degenerate sizes
    embeds, order = _embeds_and_order(1, 2, 3, False)
    check_incremental_clique(embeds, order, tau=0.0, gmax=1)


def test_greedy_clique_deterministic():
    for seed, clustered in ((0, False), (1, True)):
        embeds, _ = _embeds_and_order(18, 5, seed, clustered)
        check_greedy_clique(embeds, tau=0.2, gmax=5)


def test_flatten_groups_round_trip_deterministic():
    check_flatten_round_trip([[0, 1, 2, 3, 4, 5, 6], [7, 8], [9]], 4)
    check_flatten_round_trip([], 3)
    check_flatten_round_trip([[0]], 1)


def test_hypothesis_path_active_when_installed():
    """Documents which mode this environment runs the suite in (and makes
    the optional dependency's state visible in -v output)."""
    assert HAVE_HYPOTHESIS in (True, False)
