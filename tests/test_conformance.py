"""Golden-file conformance suite: tiny-config end-to-end samples.

For every sampler × step_impl the same fixed arrival trace runs through
the streaming scheduler twice — packed tick execution and the per-group
oracle — and must produce byte-identical completions (the packing parity
bar).  The packed result is additionally fingerprinted (shape + dtype +
sha256 + first-k values) against ``tests/golden/conformance.json``, so a
future kernel or scheduler refactor that shifts numerics diffs against a
stable committed oracle instead of only against itself.

Regenerating the goldens (after an *intentional* numerics change):

    REPRO_GOLDEN_REGEN=1 PYTHONPATH=src python -m pytest -q \
        tests/test_conformance.py

Environment gates:

* goldens were generated on the CPU backend — on other backends the
  hash/value comparison is skipped (packed-vs-per-group parity still
  runs, it is backend-independent);
* ``step_impl="fused"`` needs the Pallas kernels, which off-TPU only run
  in interpret mode — under ``REPRO_KERNEL_INTERPRET=off`` on a non-TPU
  backend the fused cases skip (CI runs the suite in BOTH modes; the
  reference cases prove mode-independence, since their jnp math never
  touches the interpret flag).
"""
import hashlib
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.data.synthetic import ShapesDataset
from repro.kernels.dispatch import resolve_interpret
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.scheduler import RequestScheduler

CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden" / "conformance.json"
FIRST_K = 8
CASES = [("ddim", "reference"), ("ddim", "fused"),
         ("dpmpp", "reference"), ("dpmpp", "fused")]


def _skip_unavailable(step_impl):
    if (step_impl == "fused" and not resolve_interpret("auto")
            and jax.default_backend() != "tpu"):
        pytest.skip("fused step kernels need interpret mode off-TPU "
                    "(REPRO_KERNEL_INTERPRET=off)")


def _run(sampler, step_impl, packed):
    """The fixed conformance trace: two waves of three themed prompts,
    grouped at tau_min=0.2, T=4 sliced in 2-step segments."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2, sampler=sampler, step_impl=step_impl)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=3, slice_steps=2, max_wait_ticks=1,
                             packed=packed, seed=0)
    _, prompts = ShapesDataset(res=16).batch(0, 3)
    done, t = [], 0.0
    for _ in range(2):
        sched.submit(prompts, now=t)
        while sched.pending:
            t += 1.0
            done.extend(sched.tick(now=t))
    assert len(done) == 2 * len(prompts)
    return done


def _fingerprint(done):
    imgs = np.stack([c.image for c in done])
    flat = imgs.reshape(-1)
    return {
        "shape": list(imgs.shape),
        "dtype": str(imgs.dtype),
        "sha256": hashlib.sha256(np.ascontiguousarray(imgs).tobytes()
                                 ).hexdigest(),
        "first_k": [float(v) for v in flat[:FIRST_K]],
    }


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_packed_matches_per_group_bitwise(sampler, step_impl):
    """The acceptance bar: packed == per-group, exact, same dtype, for
    every sampler × step_impl, across segment boundaries."""
    _skip_unavailable(step_impl)
    dp = _run(sampler, step_impl, packed=True)
    dg = _run(sampler, step_impl, packed=False)
    assert [c.prompt for c in dp] == [c.prompt for c in dg]
    for a, b in zip(dp, dg):
        assert a.image.dtype == b.image.dtype
        np.testing.assert_array_equal(a.image, b.image)
        assert a.group_id == b.group_id and a.nfe_share == b.nfe_share


def _run_policy(sampler, step_impl, policy):
    """Staggered-arrival policy trace: a full wave of three themed
    prompts at t=1 (launches full under every policy), then a lone
    straggler at t=2 that never fills its group — eager launches it at
    ``max_wait_ticks``, pad_aware holds it ``hold_ticks`` longer before
    the hold expires.  Same compositions either way, so outputs must be
    bitwise identical (init noise is drawn per-gid, launch-time
    independent)."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2, sampler=sampler, step_impl=step_impl)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=3, slice_steps=2, max_wait_ticks=1,
                             packed=True, policy=policy, seed=0)
    _, prompts = ShapesDataset(res=16).batch(0, 3)
    done, t = [], 0.0
    for wave in (prompts, prompts[:1]):
        t += 1.0
        sched.submit(wave, now=t)
        done.extend(sched.tick(now=t))
    while sched.pending:
        t += 1.0
        done.extend(sched.tick(now=t))
    assert len(done) == len(prompts) + 1
    return sched, done


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_pad_aware_matches_eager(sampler, step_impl):
    """Launch-policy equivalence: with equal group compositions the
    policy choice is NFE-invariant and bitwise-invisible — pad_aware may
    shift WHEN a group launches (the straggler is held past its eager
    launch tick) but never what it computes; the launch ledger can only
    shrink."""
    _skip_unavailable(step_impl)
    se, de = _run_policy(sampler, step_impl, "eager")
    sp, dp = _run_policy(sampler, step_impl, "pad_aware")
    assert [c.prompt for c in dp] == [c.prompt for c in de]
    for a, b in zip(dp, de):
        assert a.image.dtype == b.image.dtype
        np.testing.assert_array_equal(a.image, b.image)
        assert a.group_id == b.group_id and a.nfe_share == b.nfe_share
    assert sp.stats["nfe"] == se.stats["nfe"]
    assert sp.stats["launches"] <= se.stats["launches"]
    # the hold is visible in the straggler's latency, nowhere else
    assert max(sp.latencies) > max(se.latencies)


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_golden_fingerprint(sampler, step_impl):
    """End-to-end output vs the committed fingerprint (CPU backend)."""
    _skip_unavailable(step_impl)
    if jax.default_backend() != "cpu":
        pytest.skip("goldens were generated on the CPU backend")
    case = f"{sampler}-{step_impl}"
    fp = _fingerprint(_run(sampler, step_impl, packed=True))

    if os.environ.get("REPRO_GOLDEN_REGEN"):
        GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
        golden = (json.loads(GOLDEN_PATH.read_text())
                  if GOLDEN_PATH.exists() else {})
        golden[case] = fp
        GOLDEN_PATH.write_text(json.dumps(golden, indent=1, sort_keys=True)
                               + "\n")
        pytest.skip(f"regenerated golden for {case}")

    assert GOLDEN_PATH.exists(), (
        "tests/golden/conformance.json missing — regenerate with "
        "REPRO_GOLDEN_REGEN=1")
    golden = json.loads(GOLDEN_PATH.read_text())
    assert case in golden, f"no golden entry for {case} — regenerate"
    want = golden[case]
    assert fp["shape"] == want["shape"]
    assert fp["dtype"] == want["dtype"]
    np.testing.assert_allclose(fp["first_k"], want["first_k"],
                               rtol=0, atol=1e-6)
    assert fp["sha256"] == want["sha256"], (
        f"{case}: end-to-end bytes diverged from the committed oracle "
        "(first-8 values still within 1e-6). If the numerics change is "
        "intentional, regenerate with REPRO_GOLDEN_REGEN=1.")
