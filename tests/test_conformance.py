"""Golden-file conformance suite: tiny-config end-to-end samples.

For every sampler × step_impl the same fixed arrival trace runs through
the streaming scheduler twice — packed tick execution and the per-group
oracle — and must produce byte-identical completions (the packing parity
bar).  The packed result is additionally fingerprinted (shape + dtype +
sha256 + first-k values) against ``tests/golden/conformance.json``, so a
future kernel or scheduler refactor that shifts numerics diffs against a
stable committed oracle instead of only against itself.

Regenerating the goldens (after an *intentional* numerics change):

    REPRO_GOLDEN_REGEN=1 PYTHONPATH=src python -m pytest -q \
        tests/test_conformance.py

Environment gates:

* goldens were generated on the CPU backend — on other backends the
  hash/value comparison is skipped (packed-vs-per-group parity still
  runs, it is backend-independent);
* ``step_impl="fused"`` needs the Pallas kernels, which off-TPU only run
  in interpret mode — under ``REPRO_KERNEL_INTERPRET=off`` on a non-TPU
  backend the fused cases skip (CI runs the suite in BOTH modes; the
  reference cases prove mode-independence, since their jnp math never
  touches the interpret flag).
"""
import hashlib
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.data.synthetic import ShapesDataset
from repro.kernels.dispatch import resolve_interpret
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.scheduler import RequestScheduler
from repro.serving.trunk_cache import TrunkCache

CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden" / "conformance.json"
FIRST_K = 8
CASES = [("ddim", "reference"), ("ddim", "fused"),
         ("dpmpp", "reference"), ("dpmpp", "fused")]


def _skip_unavailable(step_impl):
    if (step_impl == "fused" and not resolve_interpret("auto")
            and jax.default_backend() != "tpu"):
        pytest.skip("fused step kernels need interpret mode off-TPU "
                    "(REPRO_KERNEL_INTERPRET=off)")


def _run(sampler, step_impl, packed):
    """The fixed conformance trace: two waves of three themed prompts,
    grouped at tau_min=0.2, T=4 sliced in 2-step segments."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2, sampler=sampler, step_impl=step_impl)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=3, slice_steps=2, max_wait_ticks=1,
                             packed=packed, seed=0)
    _, prompts = ShapesDataset(res=16).batch(0, 3)
    done, t = [], 0.0
    for _ in range(2):
        sched.submit(prompts, now=t)
        while sched.pending:
            t += 1.0
            done.extend(sched.tick(now=t))
    assert len(done) == 2 * len(prompts)
    return done


def _fingerprint(done):
    imgs = np.stack([c.image for c in done])
    flat = imgs.reshape(-1)
    return {
        "shape": list(imgs.shape),
        "dtype": str(imgs.dtype),
        "sha256": hashlib.sha256(np.ascontiguousarray(imgs).tobytes()
                                 ).hexdigest(),
        "first_k": [float(v) for v in flat[:FIRST_K]],
    }


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_packed_matches_per_group_bitwise(sampler, step_impl):
    """The acceptance bar: packed == per-group, exact, same dtype, for
    every sampler × step_impl, across segment boundaries."""
    _skip_unavailable(step_impl)
    dp = _run(sampler, step_impl, packed=True)
    dg = _run(sampler, step_impl, packed=False)
    assert [c.prompt for c in dp] == [c.prompt for c in dg]
    for a, b in zip(dp, dg):
        assert a.image.dtype == b.image.dtype
        np.testing.assert_array_equal(a.image, b.image)
        assert a.group_id == b.group_id and a.nfe_share == b.nfe_share


def _run_policy(sampler, step_impl, policy):
    """Staggered-arrival policy trace: a full wave of three themed
    prompts at t=1 (launches full under every policy), then a lone
    straggler at t=2 that never fills its group — eager launches it at
    ``max_wait_ticks``, pad_aware holds it ``hold_ticks`` longer before
    the hold expires.  Same compositions either way, so outputs must be
    bitwise identical (init noise is drawn per-gid, launch-time
    independent)."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2, sampler=sampler, step_impl=step_impl)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=3, slice_steps=2, max_wait_ticks=1,
                             packed=True, policy=policy, seed=0)
    _, prompts = ShapesDataset(res=16).batch(0, 3)
    done, t = [], 0.0
    for wave in (prompts, prompts[:1]):
        t += 1.0
        sched.submit(wave, now=t)
        done.extend(sched.tick(now=t))
    while sched.pending:
        t += 1.0
        done.extend(sched.tick(now=t))
    assert len(done) == len(prompts) + 1
    return sched, done


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_pad_aware_matches_eager(sampler, step_impl):
    """Launch-policy equivalence: with equal group compositions the
    policy choice is NFE-invariant and bitwise-invisible — pad_aware may
    shift WHEN a group launches (the straggler is held past its eager
    launch tick) but never what it computes; the launch ledger can only
    shrink."""
    _skip_unavailable(step_impl)
    se, de = _run_policy(sampler, step_impl, "eager")
    sp, dp = _run_policy(sampler, step_impl, "pad_aware")
    assert [c.prompt for c in dp] == [c.prompt for c in de]
    for a, b in zip(dp, de):
        assert a.image.dtype == b.image.dtype
        np.testing.assert_array_equal(a.image, b.image)
        assert a.group_id == b.group_id and a.nfe_share == b.nfe_share
    assert sp.stats["nfe"] == se.stats["nfe"]
    assert sp.stats["launches"] <= se.stats["launches"]
    # the hold is visible in the straggler's latency, nowhere else
    assert max(sp.latencies) > max(se.latencies)


def _check_golden(case, fp):
    """Regenerate-or-compare a fingerprint against the committed goldens
    (shared by the plain and cache-interleave golden cases)."""
    if os.environ.get("REPRO_GOLDEN_REGEN"):
        GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
        golden = (json.loads(GOLDEN_PATH.read_text())
                  if GOLDEN_PATH.exists() else {})
        golden[case] = fp
        GOLDEN_PATH.write_text(json.dumps(golden, indent=1, sort_keys=True)
                               + "\n")
        pytest.skip(f"regenerated golden for {case}")

    assert GOLDEN_PATH.exists(), (
        "tests/golden/conformance.json missing — regenerate with "
        "REPRO_GOLDEN_REGEN=1")
    golden = json.loads(GOLDEN_PATH.read_text())
    assert case in golden, f"no golden entry for {case} — regenerate"
    want = golden[case]
    # "shape" for stacked homogeneous cases, "shapes" for ragged hetero
    skey = "shape" if "shape" in fp else "shapes"
    assert fp[skey] == want[skey]
    assert fp["dtype"] == want["dtype"]
    np.testing.assert_allclose(fp["first_k"], want["first_k"],
                               rtol=0, atol=1e-6)
    assert fp["sha256"] == want["sha256"], (
        f"{case}: end-to-end bytes diverged from the committed oracle "
        "(first-8 values still within 1e-6). If the numerics change is "
        "intentional, regenerate with REPRO_GOLDEN_REGEN=1.")


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_golden_fingerprint(sampler, step_impl):
    """End-to-end output vs the committed fingerprint (CPU backend)."""
    _skip_unavailable(step_impl)
    if jax.default_backend() != "cpu":
        pytest.skip("goldens were generated on the CPU backend")
    _check_golden(f"{sampler}-{step_impl}",
                  _fingerprint(_run(sampler, step_impl, packed=True)))


def _run_cache_interleave(sampler, step_impl, index):
    """The cache-interleave trace: wave A (three themed prompts) runs to
    completion and seeds the trunk cache; wave B (a two-prompt subset of
    the same themes) arrives after — its group centroid quantizes to a
    DIFFERENT exact key but lies within ``tau_trunk`` cosine of wave A's
    trunk, so the hit must come through the index's similarity search
    (exact_hits stays 0), forking wave B's branch phase straight off the
    cached branch-point latent."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2, sampler=sampler, step_impl=step_impl)
    cache = TrunkCache(tau_trunk=0.9, index=index)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=3, slice_steps=2, max_wait_ticks=1,
                             packed=True, seed=0, trunk_cache=cache)
    _, prompts = ShapesDataset(res=16).batch(0, 3)
    done, t = [], 0.0
    for wave in (prompts, prompts[:2]):
        sched.submit(wave, now=t)
        while sched.pending:
            t += 1.0
            done.extend(sched.tick(now=t))
    assert len(done) == 5
    # the trace only works if the cache actually interleaved: one miss
    # (wave A seeds), one similarity hit (wave B forks), no exact-key
    # shortcut that would bypass the index under test
    assert cache.stats["hits"] == 1 and cache.stats["exact_hits"] == 0
    assert cache.stats["misses"] == 1 and cache.stats["inserts"] == 1
    assert sched.stats["nfe_saved_cache"] > 0
    return sched, done


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_cache_interleave_lsh_matches_scan(sampler, step_impl):
    """A scan-index hit and an LSH-index hit on the same trace fork
    bitwise-identical branch phases: the index only changes HOW the
    cached trunk is found, never what is computed from it."""
    _skip_unavailable(step_impl)
    ss, ds = _run_cache_interleave(sampler, step_impl, "scan")
    sl, dl = _run_cache_interleave(sampler, step_impl, "lsh")
    assert [c.prompt for c in dl] == [c.prompt for c in ds]
    for a, b in zip(dl, ds):
        assert a.image.dtype == b.image.dtype
        np.testing.assert_array_equal(a.image, b.image)
        assert a.group_id == b.group_id and a.nfe_share == b.nfe_share
    assert sl.stats["nfe"] == ss.stats["nfe"]
    assert sl.stats["nfe_saved_cache"] == ss.stats["nfe_saved_cache"]


# ---------------------------------------------------------------------------
# heterogeneous packs: shapes / tiers / mixed samplers
# ---------------------------------------------------------------------------

_C = CFG.latent_channels
HETERO_SHAPES = [(8, 8, _C), (4, 4, _C), (4, 8, _C)]
HETERO_TIERS = ["draft", "standard", "premium"]


def _run_hetero(kind, sampler, step_impl, packed):
    """The hetero conformance traces: six themed prompts submitted in one
    wave with per-prompt hetero axes, drained by the streaming tick loop.

    * ``hetero_shapes``       — requests cycle three latent geometries
      (square full-res, square quarter, landscape half), one sampler;
    * ``hetero_tiers``        — full-res requests cycle the three quality
      tiers (total_steps 2 / 4 / 6 at T=4), one sampler;
    * ``hetero_mixed_sampler``— full-res standard-tier requests alternate
      ddim/dpmpp under ``mix_samplers=True``, so packed ticks run both
      solvers inside single stacked launches (row-level dispatch).
    """
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2, sampler=sampler, step_impl=step_impl)
    # max_wait_ticks=0: every group (full or not) launches on its first
    # tick, so same-bucket groups sit at aligned grid positions and the
    # packed run demonstrably collapses launches (vs merely matching)
    sched = RequestScheduler(
        CFG, sage, PARAMS, TEXT_PARAMS, TC, group_size=3, slice_steps=2,
        max_wait_ticks=0, packed=packed, seed=0,
        mix_samplers=(kind == "hetero_mixed_sampler"))
    # 12 prompts for the shape trace: 4 per shape -> >=2 groups per shape
    # bucket (group_size=3), so packed ticks genuinely collapse same-shape
    # launches instead of degenerating to one group per bucket
    _, prompts = ShapesDataset(res=16).batch(
        0, 12 if kind == "hetero_shapes" else 6)
    kw = {}
    if kind == "hetero_shapes":
        kw["shape"] = [HETERO_SHAPES[i % 3] for i in range(len(prompts))]
    elif kind == "hetero_tiers":
        kw["tier"] = [HETERO_TIERS[i % 3] for i in range(len(prompts))]
    elif kind == "hetero_mixed_sampler":
        kw["sampler"] = [("ddim", "dpmpp")[i % 2]
                         for i in range(len(prompts))]
    else:
        raise ValueError(kind)
    sched.submit(prompts, now=0.0, **kw)
    done, t = [], 0.0
    while sched.pending:
        t += 1.0
        done.extend(sched.tick(now=t))
    assert len(done) == len(prompts)
    return sched, done


def _fingerprint_ragged(done):
    """Fingerprint over per-request images of HETEROGENEOUS shapes (no
    ``np.stack``): sha over the concatenation of each image's bytes, the
    per-image shape list, and the first-k values of the first image."""
    h = hashlib.sha256()
    for c in done:
        h.update(np.ascontiguousarray(c.image).tobytes())
    return {
        "shapes": [list(c.image.shape) for c in done],
        "dtype": str(done[0].image.dtype),
        "sha256": h.hexdigest(),
        "first_k": [float(v) for v in
                    np.asarray(done[0].image).reshape(-1)[:FIRST_K]],
    }


HETERO_CASES = ([(k, s, i) for k in ("hetero_shapes", "hetero_tiers")
                 for s, i in CASES]
                + [("hetero_mixed_sampler", "ddim", i)
                   for i in ("reference", "fused")])


@pytest.mark.parametrize("kind,sampler,step_impl", HETERO_CASES)
def test_hetero_packed_matches_per_group_bitwise(kind, sampler, step_impl):
    """The hetero acceptance bar: multi-shape / multi-tier /
    mixed-sampler packed ticks == the per-group oracle, exact."""
    _skip_unavailable(step_impl)
    sp, dp = _run_hetero(kind, sampler, step_impl, packed=True)
    sg, dg = _run_hetero(kind, sampler, step_impl, packed=False)
    assert [c.prompt for c in dp] == [c.prompt for c in dg]
    for a, b in zip(dp, dg):
        assert a.image.dtype == b.image.dtype
        np.testing.assert_array_equal(a.image, b.image)
        assert a.group_id == b.group_id and a.nfe_share == b.nfe_share
        assert a.tier == b.tier
    assert sp.stats["nfe"] == sg.stats["nfe"]
    # the trace exercised heterogeneity: >1 bucket along the kind's axis
    if kind == "hetero_shapes":
        assert len(sp.shape_stats) == 3
    elif kind == "hetero_tiers":
        assert len(sp.tier_stats) == 3
    else:
        assert sp.mix_samplers
    # packing still collapses launches under heterogeneity
    assert sp.stats["launches"] < sg.stats["launches"]


@pytest.mark.parametrize("kind,sampler,step_impl", HETERO_CASES)
def test_hetero_golden_fingerprint(kind, sampler, step_impl):
    """Hetero end-to-end outputs vs the committed fingerprints (CPU)."""
    _skip_unavailable(step_impl)
    if jax.default_backend() != "cpu":
        pytest.skip("goldens were generated on the CPU backend")
    _, done = _run_hetero(kind, sampler, step_impl, packed=True)
    case = (f"{kind}-{step_impl}" if kind == "hetero_mixed_sampler"
            else f"{kind}-{sampler}-{step_impl}")
    _check_golden(case, _fingerprint_ragged(done))


@pytest.mark.parametrize("sampler,step_impl", CASES)
def test_cache_interleave_lsh_golden(sampler, step_impl):
    """The LSH-hit output is additionally pinned against the committed
    oracle, so a future index or tiering refactor that perturbs the
    forked branch phase diffs against a stable fingerprint."""
    _skip_unavailable(step_impl)
    if jax.default_backend() != "cpu":
        pytest.skip("goldens were generated on the CPU backend")
    _, done = _run_cache_interleave(sampler, step_impl, "lsh")
    _check_golden(f"cache_interleave_lsh-{sampler}-{step_impl}",
                  _fingerprint(done))
