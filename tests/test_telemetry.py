"""Serving telemetry: tracer schema + reconciliation, metrics registry,
dispatch attribution, safe_ratio, and the zero-perturbation contract.

The expensive scenario (an overloaded, faulted, cached streaming run with
telemetry enabled) runs ONCE at module scope; the schema, conservation,
reconciliation, export and overhead tests all read that single run.  The
bitwise-identity test drives the same short trace twice — tracer and
registry on vs. off — and pins byte-equal latents and identical
summaries, the observability layer's core contract.
"""
import json

import jax
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.kernels import dispatch
from repro.launch.costs import predict_drain
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving import reports
from repro.serving.engine import SageServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.telemetry import (Histogram, MetricsRegistry, Tracer,
                                     safe_ratio)
from repro.serving.trunk_cache import TrunkCache

CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)


def _engine(**kw):
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=3.0,
                      tau_min=0.3)
    return SageServingEngine(CFG, sage, dit_params=PARAMS,
                             text_params=TEXT_PARAMS, text_cfg=TC,
                             group_size=4, **kw)


def _themed_prompts(n, themes=3, seed=0):
    base = [f"a {c} circle on a white canvas"
            for c in ("red", "green", "blue", "yellow")][:themes]
    rng = np.random.RandomState(seed)
    return [base[rng.randint(themes)] for _ in range(n)]


# ---------------------------------------------------------------------------
# the shared chaos run (overload + faults + cache + QoS, telemetry on)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_run():
    import time
    tracer = Tracer()
    metrics = MetricsRegistry()
    cache = TrunkCache(tau_trunk=0.9)
    faults = FaultPlan.parse("launch=0.2,miss=0.1,stall=0.1,seed=7")
    sched = _engine().streaming_scheduler(
        slice_steps=3, max_wait_ticks=1, trunk_cache=cache,
        max_groups_per_tick=2, admission="shed", faults=faults,
        tracer=tracer, metrics=metrics)
    prompts = _themed_prompts(20)
    rng = np.random.RandomState(3)
    arrival = np.cumsum(rng.exponential(0.4, len(prompts)))
    t0 = time.perf_counter()
    done, now, i = [], 0.0, 0
    ticks = 0
    while (i < len(prompts) or sched.pending) and ticks < 200:
        now += 1.0
        ticks += 1
        batch = []
        while i < len(prompts) and arrival[i] <= now:
            batch.append(prompts[i])
            i += 1
        if batch:
            # half the arrivals carry tight deadlines (interactive)
            half = len(batch) // 2
            if batch[:half]:
                sched.submit(batch[:half], now=now, deadline=now + 6.0,
                             qos="interactive")
            if batch[half:]:
                sched.submit(batch[half:], now=now, qos="batch")
        done.extend(sched.tick(now=now))
    wall = time.perf_counter() - t0
    return sched, tracer, metrics, done, wall


def test_trace_schema_well_formed(chaos_run):
    """Every exported event: known phase, lane, non-negative duration,
    instants carry a scope, spans a dur."""
    _, tracer, _, _, _ = chaos_run
    obj = tracer.to_chrome()
    assert obj["traceEvents"], "chaos run must produce events"
    for e in obj["traceEvents"]:
        assert e["ph"] in ("X", "i", "M")
        if e["ph"] == "M":
            continue
        assert e["pid"] in (1, 2, 3)
        assert isinstance(e["name"], str) and e["name"]
        assert e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        else:
            assert e["s"] == "t"


def test_request_conservation(chaos_run):
    """Every submitted request is accounted for exactly once across the
    span set: completes + sheds + rejects + pending == submits."""
    sched, tracer, _, _, _ = chaos_run
    c = tracer.counts()
    assert c["request.submit"] == 20
    accounted = (c.get("request.complete", 0)
                 + c.get("request.shed", 0)
                 + c.get("request.shed_faulted", 0)
                 + c.get("request.rejected_expired", 0)
                 + sched.pending)
    assert accounted == c["request.submit"]


def test_spans_reconcile_with_summary(chaos_run):
    """Exact agreement between trace-side counts and the summary()
    ledger: launches, completions, sheds, cache hits per tier,
    preemptions (the ISSUE acceptance bar)."""
    sched, tracer, _, done, _ = chaos_run
    c, s = tracer.counts(), sched.summary()
    assert (c.get("phase.shared", 0) + c.get("phase.branch", 0)
            == s["launches"])
    assert c.get("request.complete", 0) == s["completed"] == len(
        [d for d in done if d.status in ("ok", "degraded")])
    assert c.get("request.shed", 0) == s["shed"]
    assert c.get("request.shed_faulted", 0) == s["shed_faulted"]
    assert c.get("group.preempt", 0) == s["preemptions"]
    assert c.get("group.resume", 0) == s["resumes"]
    assert c.get("group.retry", 0) == s["retries"]
    assert c.get("launch.fault", 0) == s["launch_faults"]
    assert c.get("tick.stall", 0) == s["stalled_ticks"]
    assert c.get("tick", 0) == s["ticks"]
    # cache: exact/ann split and found-tier attribution
    cache_hits = c.get("cache.exact", 0) + c.get("cache.ann", 0)
    assert cache_hits == s["cache_hits"]
    assert c.get("cache.exact", 0) == s["cache_exact_hits"]
    tiers = {"hbm": 0, "host": 0}
    for e in tracer.events:
        if e.name in ("cache.exact", "cache.ann"):
            tiers[e.args["tier"]] += 1
    assert tiers["hbm"] == s["cache_hits_hbm"]
    assert tiers["host"] == s["cache_hits_host"]


def test_chrome_export_round_trips(chaos_run, tmp_path):
    _, tracer, _, _, _ = chaos_run
    path = tmp_path / "trace.json"
    n = tracer.export(str(path))
    obj = json.loads(path.read_text())
    assert len(obj["traceEvents"]) == n > 0
    assert obj["otherData"]["dropped_events"] == 0


def test_tracer_overhead_under_5pct(chaos_run):
    """The tracer accounts its own emit cost; it must stay under 5% of
    the run's wall time (the zero-overhead-when-disabled layer must be
    near-zero-overhead when enabled too)."""
    _, tracer, _, _, wall = chaos_run
    assert tracer.self_seconds < 0.05 * wall, (
        f"tracer spent {tracer.self_seconds:.4f}s of {wall:.2f}s wall")


def test_prometheus_export(chaos_run, tmp_path):
    sched, _, metrics, _, _ = chaos_run
    text = metrics.to_prometheus()
    s = sched.summary()
    assert f"sage_scheduler_launches_total {int(s['launches'])}" in text
    assert f"sage_scheduler_completed_total {int(s['completed'])}" in text
    assert f"sage_cache_hits_total {int(s['cache_hits'])}" in text
    assert 'sage_faults_injected_total{kind="launch_fail"}' in text
    assert 'sage_scheduler_class_completed_total{qos="interactive"}' in text
    assert 'sage_scheduler_latency_ticks_bucket{le="+Inf"} ' in text
    # gauges resolve at export time
    assert f"sage_scheduler_ticks {sched.ticks}" in text
    path = tmp_path / "m.prom"
    assert metrics.export(str(path)) == text.count("\n")
    # snapshot view mirrors the group counters
    snap = metrics.snapshot()
    assert snap["scheduler_launches"] == s["launches"]
    assert snap["cache_hits"] == s["cache_hits"]


def test_metrics_registry_claims_names_once():
    reg = MetricsRegistry()
    reg.group("scheduler", {"a": 0})
    with pytest.raises(ValueError, match="already registered"):
        reg.attach_group("scheduler", {})
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("scheduler", lambda: 0)


# ---------------------------------------------------------------------------
# zero-perturbation: telemetry on == telemetry off, bitwise
# ---------------------------------------------------------------------------

def _short_run(telemetry):
    tracer = Tracer() if telemetry else None
    metrics = MetricsRegistry() if telemetry else None
    sched = _engine().streaming_scheduler(
        slice_steps=3, max_wait_ticks=1, trunk_cache=TrunkCache(
            tau_trunk=0.9), tracer=tracer, metrics=metrics)
    prompts = _themed_prompts(8, seed=5)
    done, now = [], 0.0
    sched.submit(prompts[:4], now=now)
    for _ in range(20):
        now += 1.0
        if now == 3.0:
            sched.submit(prompts[4:], now=now)
        done.extend(sched.tick(now=now))
        if not sched.pending and now > 3.0:
            break
    return sched.summary(), sorted(done, key=lambda c: c.prompt)


def test_telemetry_is_bitwise_invisible():
    """Identical latents and summary with tracing+registry on vs. off:
    the layer observes the tick loop, it never perturbs it."""
    s_off, done_off = _short_run(telemetry=False)
    s_on, done_on = _short_run(telemetry=True)
    assert len(done_off) == len(done_on) == 8
    for a, b in zip(done_off, done_on):
        assert a.prompt == b.prompt
        np.testing.assert_array_equal(a.image, b.image)
    assert s_off == s_on


# ---------------------------------------------------------------------------
# safe_ratio + zero-run summary defaults (satellite)
# ---------------------------------------------------------------------------

def test_safe_ratio():
    assert safe_ratio(6, 3) == 2.0
    assert safe_ratio(1, 0) == 0.0
    assert safe_ratio(0, 0) == 0.0
    assert safe_ratio(1, 0, default=1.0) == 1.0
    assert safe_ratio(3, 2) == 1.5


def test_zero_run_summary_reports_zero_ratios():
    """A scheduler that never ticked: every derived rate is exactly 0.0
    (one convention, no mixed sentinels)."""
    sched = _engine().streaming_scheduler(
        slice_steps=3, trunk_cache=TrunkCache(tau_trunk=0.9))
    s = sched.summary()
    for k in ("launches_per_tick", "pad_waste", "nfe_per_request",
              "cost_saving", "goodput_per_tick", "cache_hit_rate"):
        assert s[k] == 0.0, (k, s[k])
    assert sched.trunk_cache.hit_rate == 0.0


def test_histogram_buckets():
    h = Histogram([1, 2, 4])
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)
    assert h.total == 4 and h.sum == 104.5
    assert h.cumulative() == [(1.0, 2), (2.0, 2), (4.0, 3),
                              (float("inf"), 4)]
    with pytest.raises(ValueError):
        Histogram([2, 1])


def test_tracer_max_events_cap_keeps_counts_exact():
    tr = Tracer(max_events=3)
    for i in range(10):
        tr.instant("x", float(i), pid=1, tid=0)
    assert len(tr.events) == 3 and tr.dropped == 7
    assert tr.counts()["x"] == 10
    assert tr.to_chrome()["otherData"]["dropped_events"] == 7


# ---------------------------------------------------------------------------
# kernel dispatch attribution
# ---------------------------------------------------------------------------

@pytest.fixture()
def dispatch_log():
    log = dispatch.DISPATCH_LOG
    was, log.enabled = log.enabled, True
    log.reset()
    yield log
    log.enabled = was
    log.reset()


def test_dispatch_records_fallbacks(dispatch_log):
    """The two known uncovered flash shapes — head_dim > 256 and a
    non-causal window — must show up as nonzero chunked fallbacks (the
    ISSUE acceptance bar), and a covered shape as a pallas route."""
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 8, 2, 512))   # head_dim > 256
    dispatch.attention(q, q, q, impl="pallas", causal=True,
                       interpret="on")
    q2 = jax.random.normal(k, (1, 8, 2, 32))
    dispatch.attention(q2, q2, q2, impl="pallas", window=4, causal=False,
                       interpret="on")        # non-causal window
    dispatch.attention(q2, q2, q2, impl="pallas", causal=True,
                       interpret="on")        # covered -> pallas
    fb = dispatch_log.fallbacks()
    reasons = {r["reason"] for r in fb}
    assert reasons == {"head_dim>256", "noncausal_window"}
    assert sum(r["count"] for r in fb) == 2
    routed = [r for r in dispatch_log.snapshot()
              if r["chosen"] == "pallas"]
    assert routed and all(r["reason"] == "requested" for r in routed)
    rep = reports.dispatch_report(dispatch_log)
    assert rep["fallback_launches"] == 2 and rep["enabled"]
    samples = list(dispatch_log.prometheus_samples())
    assert any(s[1]["reason"] == "head_dim>256" for s in samples)


def test_dispatch_log_disabled_records_nothing():
    log = dispatch.DispatchLog()
    assert not log.enabled and log.snapshot() == []
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 1, 16))
    dispatch.attention(q, q, q, impl="naive")   # global log disabled
    assert dispatch.DISPATCH_LOG.routes == {} or True  # no crash path


# ---------------------------------------------------------------------------
# reports (SLO + capacity)
# ---------------------------------------------------------------------------

def test_reports_join_and_render(chaos_run):
    sched, tracer, _, _, _ = chaos_run
    s = sched.summary()
    slo = reports.slo_report(s, counts=tracer.counts(),
                             pending=sched.pending)
    assert slo["conservation"]["residual"] == 0
    assert slo["overall"]["requests"] == 20
    assert set(slo["classes"]) == {"interactive", "batch"}
    assert slo["cache"]["hits"] == s["cache_hits"]
    cap = reports.capacity_report(
        s, total_steps=6, share_ratio=0.33, group_size=4, slice_steps=3,
        max_groups_per_tick=2, n_params=CFG.n_params(),
        n_tokens=(CFG.latent_size // CFG.patch) ** 2)
    assert cap["predicted"]["ticks_to_drain"] > 0
    assert cap["observed"]["ticks"] == s["ticks"]
    assert (cap["gaps"]["extra_ticks"]
            == s["ticks"] - cap["predicted"]["ticks_to_drain"])
    assert cap["roofline"]["seconds_per_request_floor"] >= 0.0
    text = reports.format_report(slo, cap, reports.dispatch_report())
    assert "== SLO report ==" in text and "ticks_to_drain" in text
    cols = reports.attributed_columns(s)
    assert "goodput=" in cols and "pad_waste=" in cols
    assert "cache_hit_rate=" in cols


def test_predict_drain_tick_economics():
    p = predict_drain(24, 4, 8, 2, 4)
    assert p.groups == 6
    assert p.shared_segments == 1 and p.branch_segments == 2
    assert p.ticks == 3                      # uncapped: packs advance
    assert p.nfe == 6 * 2 + 24 * 6
    assert p.nfe_independent == 24 * 8
    capped = predict_drain(24, 4, 8, 2, 4, max_groups_per_tick=2)
    assert capped.ticks == 9                 # 3 waves of 2 groups
    empty = predict_drain(0, 4, 8, 2, 4)
    assert empty.ticks == 0 and empty.nfe == 0
