"""DPM-Solver++(2M) integration: with an exact eps oracle both samplers must
converge to the data point; 2M should need fewer steps (2nd order)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SageConfig
from repro.core.schedule import make_schedule
from repro.core.shared_sampling import independent_sample, shared_sample

SCHED = make_schedule(1000)


def exact_eps_fn(x0):
    """For q_t = N(a_t x0, s_t^2): the exact eps given z is (z - a x0)/s.
    x0 is tiled to the (CFG-doubled) batch of z."""
    def eps(z, t, cond):
        a = SCHED.alpha(t).reshape(-1, 1, 1, 1)
        s = SCHED.sigma(t).reshape(-1, 1, 1, 1)
        reps = z.shape[0] // x0.shape[0]
        x0b = jnp.tile(x0, (reps, 1, 1, 1))
        return (z - a * x0b) / jnp.maximum(s, 1e-4)
    return eps


def _run(sampler, steps):
    x0 = jnp.tanh(jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 1)))
    sage = SageConfig(total_steps=steps, share_ratio=0.0,
                      guidance_scale=1.0, sampler=sampler, clip_x0=2.0)
    cond = jnp.zeros((2, 4, 8))
    out = independent_sample(exact_eps_fn(x0), SCHED, sage,
                             jax.random.PRNGKey(1), cond,
                             jnp.zeros((4, 8)), (4, 4, 1))
    return float(jnp.abs(out["latents"] - x0).max())


def test_both_samplers_converge():
    err_ddim = _run("ddim", 20)
    err_dpmpp = _run("dpmpp", 20)
    assert err_ddim < 0.15, err_ddim
    assert err_dpmpp < 0.15, err_dpmpp


def test_dpmpp_better_at_few_steps():
    """2nd-order solver should beat DDIM at an 8-step budget."""
    assert _run("dpmpp", 8) <= _run("ddim", 8) + 1e-3


def test_dpmpp_shared_sampling_finite():
    x0 = jnp.tanh(jax.random.normal(jax.random.PRNGKey(2), (2, 4, 4, 1)))
    sage = SageConfig(total_steps=8, share_ratio=0.5, guidance_scale=1.0,
                      sampler="dpmpp")
    K, N = 2, 2
    cond = jnp.zeros((K, N, 4, 8))
    out = shared_sample(exact_eps_fn(
        jnp.repeat(x0, N, 0)), SCHED, sage, jax.random.PRNGKey(3),
        cond, jnp.ones((K, N)), jnp.zeros((4, 8)), (4, 4, 1))
    assert bool(jnp.all(jnp.isfinite(out["latents"])))
