"""ssd_scan Pallas kernel vs the pure-jnp SSD oracle: shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_chunked_kernel
from repro.kernels.ssd_scan.ref import ssd_ref


def _data(key, b, l, h, p, n, dtype):
    x = jax.random.normal(jax.random.fold_in(key, 0), (b, l, h, p), dtype)
    dA = -jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 1), (b, l, h))
    ).astype(jnp.float32)
    B_ = jax.random.normal(jax.random.fold_in(key, 2), (b, l, n), dtype)
    C_ = jax.random.normal(jax.random.fold_in(key, 3), (b, l, n), dtype)
    return x, dA, B_, C_


@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 16, 2, 8, 16, 8),
    (2, 32, 3, 16, 8, 16),
    (1, 64, 2, 32, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_oracle(b, l, h, p, n, chunk, dtype):
    key = jax.random.PRNGKey(b * 100 + l)
    x, dA, B_, C_ = _data(key, b, l, h, p, n, dtype)
    y, s = ssd_chunked_kernel(x, dA, B_, C_, chunk)
    y_ref, s_ref = ssd_ref(x, dA, B_, C_, chunk)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


def test_ssd_kernel_state_continuity():
    """Kernel final state must continue a split sequence correctly."""
    key = jax.random.PRNGKey(7)
    x, dA, B_, C_ = _data(key, 1, 32, 2, 8, 16, jnp.float32)
    y_full, s_full = ssd_chunked_kernel(x, dA, B_, C_, 16)
    _, s_ref = ssd_ref(x, dA, B_, C_, 8)   # different chunking, same state
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)
