"""Substrate tests: data pipeline, optimizers, checkpointing, serving engine,
shared-prefix prefill, kv-cache forking."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.config import OptimConfig, SageConfig, get_config
from repro.data.grouped import build_grouped_dataset
from repro.data.synthetic import ShapesDataset, token_stream
from repro.models import dit, transformer as tfm
from repro.models import text_encoder as te
from repro.optim.optimizers import (adafactor, adamw, apply_updates,
                                    clip_by_global_norm)
from repro.serving.engine import SageServingEngine
from repro.serving.kvcache import fork_cache, select_rows
from repro.serving.shared_prefill import (common_prefix_len,
                                          shared_prefix_prefill)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_shapes_dataset_deterministic():
    ds = ShapesDataset(res=32, seed=1)
    img1, p1 = ds.sample(7)
    img2, p2 = ds.sample(7)
    np.testing.assert_array_equal(img1, img2)
    assert p1 == p2
    assert img1.shape == (32, 32, 3)
    assert img1.min() >= -1.0 and img1.max() <= 1.0


def test_grouped_dataset_build():
    tc = te.text_cfg(dim=64, layers=2)
    tp = te.init_text(jax.random.PRNGKey(0), tc)

    def encode(prompts):
        toks = te.tokenize(prompts, max_len=24)
        return te.encode_text(tp, tc, toks)

    gd = build_grouped_dataset(encode, n_items=48, res=16, tau_min=0.3)
    assert sorted(i for g in gd.groups for i in g) == list(range(48))
    batches = list(gd.iter_batches(k_groups=2, group_size=3))
    assert batches, "no batches produced"
    b = batches[0]
    assert b["images"].shape[:2] == (2, 3)
    assert b["cond"].shape[:2] == (2, 3)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [lambda: adamw(), lambda: adafactor()])
def test_optimizer_reduces_quadratic(make):
    opt = make()
    params = {"w": jnp.array([3.0, -2.0]), "m": jnp.ones((4, 4))}
    state = opt.init(params)
    for _ in range(60):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2)
                         + jnp.sum(p["m"] ** 2))(params)
        updates, state = opt.update(grads, state, params, 0.1)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert float(jnp.abs(params["m"]).max()) < 0.5


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros(2), jnp.ones(3)]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, tree)
        save_checkpoint(d, 9, tree)
        assert latest_step(d) == 9
        back = restore_checkpoint(d, 5, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))


# ---------------------------------------------------------------------------
# kv cache ops + shared prefix prefill
# ---------------------------------------------------------------------------

def test_fork_and_select():
    cache = {"k": jnp.arange(12.0).reshape(2, 3, 2)[0:1]}
    f = fork_cache(cache, 3)
    assert f["k"].shape == (3, 3, 2)
    np.testing.assert_array_equal(np.asarray(f["k"][0]),
                                  np.asarray(f["k"][2]))
    s = select_rows(f, jnp.array([2, 0]))
    assert s["k"].shape == (2, 3, 2)


def test_common_prefix_len():
    t = np.array([[1, 2, 3, 4], [1, 2, 9, 4], [1, 2, 3, 7]])
    assert common_prefix_len(t) == 2
    assert common_prefix_len(t[:1]) == 4


def test_shared_prefix_prefill_matches_independent():
    """Forked-trunk decoding must produce identical logits to full prefill."""
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    N, S, P = 3, 12, 7
    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab, (1, P)).repeat(N, axis=0)
    tails = rng.randint(0, cfg.vocab, (N, S - P))
    tokens = np.concatenate([shared, tails], axis=1)

    def prefill_fn(t, max_len):
        return tfm.prefill(params, cfg, jnp.asarray(t), max_len=max_len)

    def decode_fn(cache, tok, pos):
        return tfm.decode_step(params, cfg, cache, jnp.asarray(tok), pos)

    logits, caches, pos, stats = shared_prefix_prefill(
        prefill_fn, decode_fn, tokens, max_len=S + 4)
    assert stats["prefix_len"] == P
    assert stats["saving"] > 0

    ref, _ = tfm.prefill(params, cfg, jnp.asarray(tokens), max_len=S + 4)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(ref[:, 0], np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# serving engine (end-to-end on smoke DiT)
# ---------------------------------------------------------------------------

def test_serving_engine_end_to_end():
    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=3.0,
                      tau_min=0.2)
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    engine = SageServingEngine(
        cfg, sage,
        dit_params=dit.init_params(cfg, jax.random.PRNGKey(0)),
        text_params=te.init_text(jax.random.PRNGKey(1), tc),
        text_cfg=tc, group_size=3)
    ds = ShapesDataset(res=16)
    _, prompts = ds.batch(0, 9)
    engine.submit(prompts)
    done = engine.step(max_batch=9)
    assert len(done) == 9
    assert all(np.isfinite(c.image).all() for c in done)
    # grouping must produce at least one multi-member group on this corpus
    assert engine.cost_saving >= 0.0
    assert engine.stats["requests"] == 9
