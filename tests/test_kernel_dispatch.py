"""Dispatch-layer parity: the pallas backends must match the naive jnp
paths everywhere the sampling hot loop uses them — attention (self and
cross, padded keys), the fused CFG+DDIM update, and full shared_sample
trajectories (acceptance: atol 2e-2 attention / 1e-4 fused update)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SageConfig, get_config, replace
from repro.core import samplers
from repro.core.guidance import cfg_combine
from repro.core.schedule import make_schedule
from repro.core.shared_sampling import shared_sample
from repro.kernels import dispatch
from repro.kernels.ddim_step.ops import fused_cfg_ddim_step
from repro.kernels.flash_attention.ops import flash_attention
from repro.models import attention as attn
from repro.models import dit

SCHED = make_schedule(1000)
CFG = get_config("sage-dit", smoke=True)


# ---------------------------------------------------------------------------
# interpret-mode resolution
# ---------------------------------------------------------------------------

def test_resolve_interpret_auto_and_overrides(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.resolve_interpret("auto") == (not on_tpu)
    assert dispatch.resolve_interpret("on") is True
    assert dispatch.resolve_interpret("off") is False
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "on")
    assert dispatch.resolve_interpret("off") is True  # env wins
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "off")
    assert dispatch.resolve_interpret("on") is False
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "yes-please")
    with pytest.raises(ValueError, match="REPRO_KERNEL_INTERPRET"):
        dispatch.resolve_interpret("auto")  # typo'd override fails loudly
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET")
    with pytest.raises(ValueError):
        dispatch.resolve_interpret("sometimes")


def test_dispatch_rejects_unknown_impls():
    x = jnp.zeros((1, 8, 2, 16))
    with pytest.raises(ValueError):
        dispatch.attention(x, x, x, impl="cuda")
    z = jnp.zeros((1, 4, 4, 2))
    with pytest.raises(ValueError):
        dispatch.cfg_ddim_step(z, z, z, guidance=1.0, a_t=0.9, s_t=0.44,
                               a_n=0.95, s_n=0.31, impl="magic")


# ---------------------------------------------------------------------------
# attention backend parity through gqa_full
# ---------------------------------------------------------------------------

def _attn_setup(n_kv_heads=None, dtype="float32"):
    cfg = CFG if n_kv_heads is None else replace(CFG, n_kv_heads=n_kv_heads)
    cfg = replace(cfg, dtype=dtype)
    key = jax.random.PRNGKey(0)
    p = attn.init_gqa(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.dtype(dtype))
    return cfg, p, x


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("n_kv", [None, 2])  # MHA and a real GQA fold
def test_pallas_self_attention_matches_naive(dtype, n_kv):
    cfg, p, x = _attn_setup(n_kv_heads=n_kv, dtype=dtype)
    ref = attn.gqa_full(p, replace(cfg, attn_impl="naive"), x, causal=False)
    out = attn.gqa_full(p, replace(cfg, attn_impl="pallas"), x, causal=False)
    tol = 1e-3 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("lc", [48, 77, 130])  # odd / padded key lengths
def test_pallas_cross_attention_masks_padded_keys(lc):
    cfg, p, x = _attn_setup()
    mem = jax.random.normal(jax.random.PRNGKey(7), (2, lc, cfg.d_model))
    ref = attn.gqa_full(p, replace(cfg, attn_impl="naive"), x,
                        causal=False, memory=mem)
    out = attn.gqa_full(p, replace(cfg, attn_impl="pallas"), x,
                        causal=False, memory=mem)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_pallas_window_matches_chunked_through_gqa_full():
    # window now runs the flash kernel's index-map variant, not a fallback
    cfg, p, x = _attn_setup()
    ref = attn.gqa_full(p, replace(cfg, attn_impl="chunked"), x,
                        causal=True, window=8)
    out = attn.gqa_full(p, replace(cfg, attn_impl="pallas"), x,
                        causal=True, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window,n_kv", [(64, 2), (130, 2), (300, 4)])
def test_pallas_window_multiblock_matches_chunked(window, n_kv):
    """S=512 spans 4 K blocks, so the window variant's K index-map offsets
    (start > 0) and trimmed K grid are actually exercised."""
    key = jax.random.PRNGKey(window)
    S, H, D = 512, 4, 64
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, S, n_kv, D))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, S, n_kv, D))
    ref = dispatch.attention(q, k, v, impl="chunked", causal=True,
                             window=window, block=64)
    out = dispatch.attention(q, k, v, impl="pallas", causal=True,
                             window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("D,window", [(256, 0), (200, 0), (256, 100)])
def test_pallas_wide_heads_match_chunked(D, window):
    """head_dim in (128, 256] runs the two-lane-tile D variant (and
    composes with the sliding window) instead of the chunked fallback."""
    key = jax.random.PRNGKey(D + window)
    S, H = 256, 2
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (1, S, H, D))
               for i in range(3))
    ref = dispatch.attention(q, k, v, impl="chunked", causal=True,
                             window=window, block=64)
    out = dispatch.attention(q, k, v, impl="pallas", causal=True,
                             window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_naive_noncausal_window_matches_chunked():
    # naive must apply the look-back limit too, not silently ignore it
    key = jax.random.PRNGKey(1)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (1, 32, 2, 16))
               for i in range(3))
    ref = dispatch.attention(q, k, v, impl="chunked", causal=False,
                             window=8, block=16)
    out = dispatch.attention(q, k, v, impl="naive", causal=False, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_pallas_noncausal_window_falls_back_to_chunked():
    # the one remaining fallback shape: window without causal
    cfg, p, x = _attn_setup()
    ref = attn.gqa_full(p, replace(cfg, attn_impl="chunked"), x,
                        causal=False, window=8)
    out = attn.gqa_full(p, replace(cfg, attn_impl="pallas"), x,
                        causal=False, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_gqa_prefill_pallas_matches_naive():
    # gqa_prefill now routes through dispatch instead of hard-coding
    cfg, p, x = _attn_setup()
    ref, cache_ref = attn.gqa_prefill(p, replace(cfg, attn_impl="naive"),
                                      x, max_len=32, window=8)
    out, cache = attn.gqa_prefill(p, replace(cfg, attn_impl="pallas"),
                                  x, max_len=32, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache["k"]),
                               np.asarray(cache_ref["k"]), rtol=1e-6,
                               atol=1e-6)


def test_pallas_window_and_wide_heads_do_not_route_to_chunked(monkeypatch):
    """Acceptance: sliding-window and head_dim=256 must hit the kernel, not
    the chunked fallback — poison attend_chunked and make sure the pallas
    path never calls it (and that the remaining fallback shapes still do)."""
    from repro.models import layers

    def boom(*a, **k):
        raise AssertionError("pallas path routed to attend_chunked")

    monkeypatch.setattr(layers, "attend_chunked", boom)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 64))
    dispatch.attention(q, q, q, impl="pallas", causal=True, window=64)
    qw = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 256))
    dispatch.attention(qw, qw, qw, impl="pallas", causal=True)
    with pytest.raises(AssertionError):  # head_dim > 256 still falls back
        qx = jnp.zeros((1, 128, 2, 512))
        dispatch.attention(qx, qx, qx, impl="pallas", causal=True)


def test_flash_attention_rejects_unsupported_shapes():
    q = jnp.zeros((1, 128, 2, 512))
    with pytest.raises(ValueError, match="head_dim"):
        flash_attention(q, q, q, causal=False)
    q = jnp.zeros((1, 128, 2, 64))
    with pytest.raises(ValueError, match="causal"):
        flash_attention(q, q, q, causal=False, window=8)


# ---------------------------------------------------------------------------
# fused CFG+DDIM vs cfg_combine + samplers.ddim_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 8, 8, 4), (3, 17, 5, 3), (1, 7, 9, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("clip", [0.0, 3.0])
def test_fused_step_matches_sampler_composition(shape, dtype, clip):
    key = jax.random.PRNGKey(hash((shape, clip)) % 2**31)
    z, eu, ec = (jax.random.normal(jax.random.fold_in(key, i), shape, dtype)
                 for i in range(3))
    t, t_next = jnp.int32(700), jnp.int32(466)
    w = 7.5
    eps = cfg_combine(eu, ec, w)
    ref = samplers.ddim_step(SCHED, z, t, t_next, eps, clip_x0=clip)
    a_t, s_t, a_n, s_n = samplers.ddim_scalars(SCHED, t, t_next)
    out = fused_cfg_ddim_step(z, eu, ec, w, a_t, s_t, a_n, s_n,
                              clip_x0=clip)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_dispatch_step_reference_equals_fused():
    key = jax.random.PRNGKey(3)
    z, eu, ec = (jax.random.normal(jax.random.fold_in(key, i), (2, 6, 6, 4))
                 for i in range(3))
    kw = dict(guidance=5.0, a_t=SCHED.alpha(500), s_t=SCHED.sigma(500),
              a_n=SCHED.alpha(333), s_n=SCHED.sigma(333), clip_x0=3.0)
    ref = dispatch.cfg_ddim_step(z, eu, ec, impl="reference", **kw)
    out = dispatch.cfg_ddim_step(z, eu, ec, impl="fused", **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_dispatch_group_mean_matches_reference():
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (3, 4, 8, 8, 2))
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (3, 4)) > 0.4
            ).astype(jnp.float32).at[:, 0].set(1.0)
    ref = dispatch.group_mean(x, mask, impl="reference")
    out = dispatch.group_mean(x, mask, impl="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: shared_sample naive vs pallas+fused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shared_uncond", [False, True])
def test_shared_sample_pallas_fused_matches_naive(shared_uncond):
    sched = SCHED
    key = jax.random.PRNGKey(0)
    params = dit.init_params(CFG, key)
    K, N = 2, 3
    cond = jax.random.normal(jax.random.fold_in(key, 1),
                             (K, N, CFG.cond_len, CFG.cond_dim))
    mask = jnp.ones((K, N)).at[1, 2].set(0.0)
    null = jnp.zeros((CFG.cond_len, CFG.cond_dim))
    shape = (CFG.latent_size, CFG.latent_size, CFG.latent_channels)
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=3.0,
                      shared_uncond_cfg=shared_uncond)

    def run(cfg, sg):
        return shared_sample(
            lambda z, t, c: dit.forward(params, cfg, z, t, c),
            sched, sg, key, cond, mask, null, shape)

    ref = run(replace(CFG, attn_impl="naive"), sage)
    out = run(replace(CFG, attn_impl="pallas"),
              replace(sage, step_impl="fused"))
    assert int(ref["nfe"]) == int(out["nfe"])  # fusion must not change NFE
    np.testing.assert_allclose(np.asarray(out["latents"]),
                               np.asarray(ref["latents"]),
                               rtol=2e-2, atol=2e-2)


def test_serving_engine_runs_on_pallas_backend():
    from repro.models import text_encoder as te
    from repro.serving.engine import SageServingEngine

    sage = SageConfig(total_steps=4, share_ratio=0.5, guidance_scale=2.0,
                      tau_min=0.2)
    tc = te.text_cfg(dim=CFG.cond_dim, layers=2)
    key = jax.random.PRNGKey(0)
    engine = SageServingEngine(
        CFG, sage, dit_params=dit.init_params(CFG, key),
        text_params=te.init_text(jax.random.fold_in(key, 1), tc),
        text_cfg=tc, group_size=3,
        attn_impl="pallas", step_impl="fused")
    assert engine.cfg.attn_impl == "pallas"
    assert engine.sage.step_impl == "fused"
    engine.submit(["a red circle", "a big red circle", "a blue square"])
    done = engine.step(max_batch=3)
    assert len(done) == 3
    assert all(np.isfinite(c.image).all() for c in done)
