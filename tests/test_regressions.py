"""Regression tests for bugs found during the build (EXPERIMENTS changelog)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SageConfig, get_config
from repro.core.samplers import ddim_step
from repro.core.schedule import make_schedule
from repro.data.synthetic import N_COMBOS, ShapesDataset
from repro.models import text_encoder as te


def test_corpus_prompts_unique():
    """Duplicate prompts made sim=1.0 pairs dominate the threshold graph."""
    ds = ShapesDataset(res=16, seed=0)
    prompts = [ds.sample(i)[1] for i in range(min(N_COMBOS, 400))]
    assert len(set(prompts)) == len(prompts)


def test_cond_len_covers_captions():
    """cond_len=8 truncated every caption to 'a small|a large' — all group
    members got identical conditioning (div==0, beta-invariant metrics)."""
    for name in ("sage-dit", "sage-dit-100m"):
        cfg = get_config(name, smoke=True)
        ds = ShapesDataset(res=16)
        _, prompts = ds.batch(0, 16)
        toks = np.asarray(te.tokenize(prompts, max_len=cfg.cond_len))
        # distinct prompts must stay distinct after tokenisation
        assert len({t.tobytes() for t in toks}) == len(set(prompts))


def test_ddim_clip_x0_bounds_trajectory():
    """1/alpha blow-up at t ~ T drowned member differences post-branch."""
    sched = make_schedule(1000)
    z = 10.0 * jnp.ones((1, 4, 4, 1))
    eps = jnp.zeros_like(z)
    t, tn = jnp.int32(1000), jnp.int32(966)
    wild = ddim_step(sched, z, t, tn, eps)                 # no clipping
    tame = ddim_step(sched, z, t, tn, eps, clip_x0=3.0)
    assert float(jnp.abs(wild).max()) > 1e3
    assert float(jnp.abs(tame).max()) < 10.0


def test_sage_config_clip_default_on():
    assert SageConfig().clip_x0 > 0


def test_expert_spec_keeps_stack_dim():
    """4-D stacked expert weights lost their scan-stack axis in the
    PartitionSpec (kimi-k2 compile failure)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import partition

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("kimi-k2-1t-a32b")
    path = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("l0"),
            jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("wi"))
    spec = partition.spec_for(cfg, path, (60, 384, 7168, 2048), FakeMesh(),
                              fsdp=True)
    assert spec == P(None, "model", "data", None)
    assert len(spec) == 4
