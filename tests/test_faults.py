"""Fault-injection harness: determinism, recovery, accounting.

The contract under test (the robustness acceptance bar): every injected
fault is either **recovered** — a retried segment launch or a
recomputed-after-corruption trunk produces results bitwise-identical to
the fault-free run — or **surfaced** as an accounted shed
(``status="shed"``, NFE moved to the ``nfe_wasted`` ledger).  Never a
silent drop: request conservation closes exactly on every chaos trace.
"""
import jax
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.faults import (KINDS, FaultPlan, array_crc,
                                  corrupt_array)
from repro.serving.scheduler import RequestScheduler
from repro.serving.trunk_cache import TrunkCache, TrunkEntry

CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)

SAGE = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                  tau_min=0.2)


def _sched(**kw):
    kw.setdefault("group_size", 2)
    kw.setdefault("slice_steps", 2)
    return RequestScheduler(CFG, SAGE, PARAMS, TEXT_PARAMS, TC, **kw)


def _run_trace(sched, waves, max_ticks=300):
    """Submit one wave per tick, then tick until drained (bounded)."""
    done, t = [], 0.0
    for wave in waves:
        t += 1.0
        if wave:
            sched.submit(wave, now=t)
        done.extend(sched.tick(now=t))
    while sched.pending and t < max_ticks:
        t += 1.0
        done.extend(sched.tick(now=t))
    return done


def _conserved(s, done):
    assert s.stats["requests"] == s.stats["completed"] + s.stats["shed"] \
        + s.stats["shed_faulted"] + s.stats["rejected_expired"] + s.pending
    assert len(done) == s.stats["requests"] - s.pending


# ---------------------------------------------------------------------------
# FaultPlan unit behavior
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_replay():
    """Same seed -> identical injection sequence, and each kind's stream
    is independent: enabling other kinds never changes a kind's draws."""
    a = FaultPlan(seed=5, p_launch_fail=0.3)
    b = FaultPlan(seed=5, p_launch_fail=0.3)
    seq_a = [a.launch_fails() for _ in range(64)]
    assert seq_a == [b.launch_fails() for _ in range(64)]
    assert any(seq_a) and not all(seq_a)
    # independence: interleaving other kinds leaves the stream untouched
    c = FaultPlan(seed=5, p_launch_fail=0.3, p_cache_miss=0.9,
                  p_tick_stall=0.9)
    seq_c = []
    for _ in range(64):
        c.cache_miss()
        seq_c.append(c.launch_fails())
        c.tick_stalls()
    assert seq_c == seq_a
    assert a.queries["launch_fail"] == 64
    assert a.injected["launch_fail"] == sum(seq_a)
    assert a.total_injected == sum(seq_a)


def test_fault_plan_zero_probability_never_fires():
    p = FaultPlan(seed=0)
    assert not any(p.launch_fails() or p.cache_miss() or p.cache_corrupt()
                   or p.tick_stalls() for _ in range(32))
    assert p.total_injected == 0
    assert p.queries["launch_fail"] == 32


def test_fault_plan_max_faults_bound():
    p = FaultPlan(seed=1, p_launch_fail=1.0, max_faults=3)
    fired = [p.launch_fails() for _ in range(10)]
    assert fired == [True] * 3 + [False] * 7
    assert p.total_injected == 3


def test_fault_plan_validation_and_parse():
    with pytest.raises(ValueError, match="p_launch_fail"):
        FaultPlan(p_launch_fail=1.5)
    p = FaultPlan.parse("launch=0.2,miss=0.1,corrupt=0.05,stall=0.1,"
                        "seed=3,max=20")
    assert (p.p_launch_fail, p.p_cache_miss, p.p_cache_corrupt,
            p.p_tick_stall) == (0.2, 0.1, 0.05, 0.1)
    assert p.seed == 3 and p.max_faults == 20
    assert FaultPlan.parse("launch=1.0").p_cache_miss == 0.0
    with pytest.raises(ValueError, match="unknown fault-plan key"):
        FaultPlan.parse("latency=0.5")
    with pytest.raises(ValueError, match="key=value"):
        FaultPlan.parse("launch")
    assert set(KINDS) == {"launch_fail", "cache_miss", "cache_corrupt",
                          "tick_stall"}


def test_corrupt_array_breaks_crc():
    x = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    crc = array_crc(x)
    y = corrupt_array(x)
    assert y.shape == x.shape and y.dtype == x.dtype
    assert array_crc(y) != crc
    assert array_crc(x) == crc               # original untouched
    assert not np.array_equal(x, y)


# ---------------------------------------------------------------------------
# TrunkCache fault points + the always-on integrity gate
# ---------------------------------------------------------------------------

def _entry(seed=0):
    rng = np.random.RandomState(seed)
    return TrunkEntry(
        z=rng.randn(1, 4, 4, 2).astype(np.float32), eps_prev=None,
        step_idx=2, beta_bucket=0.2, rng_fold=0,
        centroid=rng.randn(8).astype(np.float32), cfg_key=("k",))


def test_cache_forced_miss_keeps_entry():
    cache = TrunkCache(tau_trunk=0.5,
                       faults=FaultPlan(seed=0, p_cache_miss=1.0))
    e = _entry()
    assert cache.insert(e)
    got = cache.lookup(e.centroid, 0.2, ("k",), (1, 4, 4, 2))
    assert got is None
    assert cache.stats["fault_forced_misses"] == 1
    assert cache.stats["misses"] == 1 and cache.stats["hits"] == 0
    assert len(cache) == 1                    # entry survives the fault


def test_cache_corruption_detected_and_dropped():
    cache = TrunkCache(tau_trunk=0.5,
                       faults=FaultPlan(seed=0, p_cache_corrupt=1.0))
    e = _entry()
    assert cache.insert(e)
    got = cache.lookup(e.centroid, 0.2, ("k",), (1, 4, 4, 2))
    assert got is None                        # CRC gate caught the damage
    assert cache.stats["integrity_drops"] == 1
    assert cache.stats["misses"] == 1 and cache.stats["hits"] == 0
    assert len(cache) == 0                    # damaged entry evicted
    assert cache.bytes == 0                   # byte ledger stays closed


def test_cache_integrity_gate_always_on():
    """External corruption (no FaultPlan at all) is still caught: the
    CRC check is part of the hit path, not of the chaos harness."""
    cache = TrunkCache(tau_trunk=0.5)
    e = _entry()
    assert cache.insert(e)
    e.z = corrupt_array(e.z)                  # rot the stored payload
    assert cache.lookup(e.centroid, 0.2, ("k",), (1, 4, 4, 2)) is None
    assert cache.stats["integrity_drops"] == 1
    assert len(cache) == 0


def test_cache_clean_hit_unaffected_by_plan_object():
    """A plan with zero probabilities must be fully transparent."""
    cache = TrunkCache(tau_trunk=0.5, faults=FaultPlan(seed=0))
    e = _entry()
    assert cache.insert(e)
    got = cache.lookup(e.centroid, 0.2, ("k",), (1, 4, 4, 2))
    assert got is e and cache.stats["hits"] == 1


# ---------------------------------------------------------------------------
# scheduler: retry recovery is bitwise, exhaustion sheds, stalls account
# ---------------------------------------------------------------------------

WAVES = [["a red circle one", "a red circle two"], [],
         ["a blue square one"], ["a blue square two"], []]


def _images(done):
    return {c.prompt: c.image for c in done}


def test_launch_fault_retry_recovers_bitwise():
    """Failed segment launches leave the carry untouched, so the retried
    computation — and therefore every completion — is bitwise-identical
    to the fault-free run, just later.  Eager policy, no cache: group
    compositions cannot differ between the runs."""
    base_s = _sched(seed=0)
    base = _run_trace(base_s, WAVES)
    assert base_s.pending == 0

    plan = FaultPlan(seed=11, p_launch_fail=0.5)
    chaos_s = _sched(seed=0, faults=plan, max_retries=10)
    chaos = _run_trace(chaos_s, WAVES)
    assert chaos_s.pending == 0
    assert plan.injected["launch_fail"] > 0   # chaos actually happened
    assert chaos_s.stats["retries"] == chaos_s.stats["launch_faults"] > 0

    bi, ci = _images(base), _images(chaos)
    assert sorted(bi) == sorted(ci)
    for p in bi:
        assert np.array_equal(bi[p], ci[p]), p
    assert all(c.status == "ok" for c in chaos)
    _conserved(chaos_s, chaos)
    # recovery is never free lunch: the faulted run can only be later
    per_prompt_base = {c.prompt: c.latency for c in base}
    for c in chaos:
        assert c.latency >= per_prompt_base[c.prompt] - 1e-9


def test_retry_exhaustion_sheds_with_accounting():
    """p=1 launch failure: after ``max_retries`` backoffs every group
    takes the shed escape hatch — members surface as accounted
    ``status='shed'`` completions and spent NFE moves to nfe_wasted."""
    plan = FaultPlan(seed=0, p_launch_fail=1.0)
    s = _sched(faults=plan, max_retries=2)
    done = _run_trace(s, WAVES)
    assert s.pending == 0
    assert done and all(c.status == "shed" for c in done)
    assert all(c.image is None for c in done)
    assert s.stats["shed_faulted"] == len(done) == s.stats["requests"]
    assert s.stats["completed"] == 0
    _conserved(s, done)
    # every group burned exactly max_retries retries before shedding
    assert s.stats["retries"] % s.max_retries == 0


def test_partial_faults_mix_recovery_and_shed():
    """Moderate fault rate with a tight retry budget: some groups
    recover, some shed — but the union is exactly the submitted set."""
    plan = FaultPlan(seed=3, p_launch_fail=0.7)
    s = _sched(faults=plan, max_retries=1)
    done = _run_trace(s, WAVES, max_ticks=400)
    assert s.pending == 0
    _conserved(s, done)
    statuses = {c.status for c in done}
    assert statuses <= {"ok", "shed"}
    # whatever shed was accounted, whatever completed is intact
    base = _images(_run_trace(_sched(seed=0), WAVES))
    for c in done:
        if c.status == "ok":
            assert np.array_equal(c.image, base[c.prompt])


def test_tick_stalls_are_pure_delay():
    """Stalled ticks advance nothing but the clock; results stay
    bitwise-identical and the stall count is surfaced."""
    base = _images(_run_trace(_sched(seed=0), WAVES))
    plan = FaultPlan(seed=2, p_tick_stall=0.4)
    s = _sched(seed=0, faults=plan)
    done = _run_trace(s, WAVES)
    assert s.pending == 0
    assert s.stats["stalled_ticks"] == plan.injected["tick_stall"] > 0
    ci = _images(done)
    assert sorted(ci) == sorted(base)
    for p in base:
        assert np.array_equal(base[p], ci[p]), p
    _conserved(s, done)


def test_corrupt_cache_equals_no_cache_run():
    """With p_cache_corrupt=1.0 every would-be trunk hit is damaged,
    caught by the CRC gate and recomputed — so the chaos run must equal
    the cache-less run bitwise, and every hit shows up as an integrity
    drop (recovery by exact recomputation, never silent reuse)."""
    waves = [["a red circle v1", "a red circle v2"], [],
             ["a red circle v3", "a red circle v4"], []]
    no_cache = _images(_run_trace(_sched(seed=0), waves))

    plan = FaultPlan(seed=0, p_cache_corrupt=1.0)
    cache = TrunkCache(tau_trunk=0.8, faults=plan)
    s = _sched(seed=0, trunk_cache=cache)
    done = _run_trace(s, waves)
    assert s.pending == 0
    ci = _images(done)
    assert sorted(ci) == sorted(no_cache)
    for p in no_cache:
        assert np.array_equal(no_cache[p], ci[p]), p
    assert cache.stats["integrity_drops"] == plan.injected["cache_corrupt"]
    assert cache.stats["hits"] == 0
    assert s.stats["nfe_saved_cache"] == 0.0
    _conserved(s, done)


def test_forced_miss_cache_equals_no_cache_run():
    waves = [["a red circle v1", "a red circle v2"], [],
             ["a red circle v3", "a red circle v4"], []]
    no_cache = _images(_run_trace(_sched(seed=0), waves))
    plan = FaultPlan(seed=0, p_cache_miss=1.0)
    cache = TrunkCache(tau_trunk=0.8, faults=plan)
    s = _sched(seed=0, trunk_cache=cache)
    ci = _images(_run_trace(s, waves))
    for p in no_cache:
        assert np.array_equal(no_cache[p], ci[p]), p
    assert cache.stats["fault_forced_misses"] > 0
    assert len(cache) > 0                    # entries survived the faults


def test_combined_chaos_conservation():
    """All fault kinds at once on a longer trace: whatever happens,
    conservation closes and anything served is bitwise-correct."""
    rng = np.random.RandomState(9)
    waves = []
    for i in range(8):
        k = rng.poisson(1.2)
        waves.append([f"a {w} no {i}.{j}" for j, w in enumerate(
            rng.choice(["red circle", "blue square"], size=k))])
    base = _images(_run_trace(_sched(seed=0), waves))
    plan = FaultPlan(seed=4, p_launch_fail=0.3, p_cache_miss=0.3,
                     p_cache_corrupt=0.3, p_tick_stall=0.2)
    s = _sched(seed=0, faults=plan, max_retries=2,
               trunk_cache=TrunkCache(tau_trunk=0.8, faults=plan))
    done = _run_trace(s, waves, max_ticks=500)
    assert s.pending == 0
    _conserved(s, done)
    assert plan.total_injected > 0
    for c in done:
        assert c.status in ("ok", "shed")
        if c.status == "ok" and not c.cache_hit:
            assert np.array_equal(c.image, base[c.prompt]), c.prompt
