"""Soft hypothesis dependency + example-budget profiles for the suite.

A bare ``from hypothesis import ...`` fails collection of the whole module
when hypothesis is absent (and module-scope ``pytest.importorskip`` would
skip every test in it, deterministic ones included).  This shim keeps the
deterministic cases runnable everywhere: when hypothesis is missing, only
the ``@given`` property tests are skipped.

Profiles (``HYPOTHESIS_PROFILE`` env var, used by ``nightly.yml``):

* ``default`` — per-test ``@settings`` budgets as written;
* ``nightly`` — every per-test ``max_examples`` is scaled by
  ``NIGHTLY_SCALE`` and runs **derandomized** (seeded from the test
  itself, so a nightly failure reproduces exactly).  The scaling lives
  here, in the exported ``settings`` wrapper, because an explicit
  per-test ``@settings(max_examples=...)`` would override any value a
  registered profile supplied.
"""
import os

import pytest

NIGHTLY_SCALE = 25
_PROFILE = os.environ.get("HYPOTHESIS_PROFILE", "default")

try:
    from hypothesis import given, strategies as st
    from hypothesis import settings as _hp_settings
    HAVE_HYPOTHESIS = True

    _hp_settings.register_profile("nightly", derandomize=True,
                                  deadline=None, print_blob=True)
    if _PROFILE != "default":
        _hp_settings.load_profile(_PROFILE)
    _SCALE = NIGHTLY_SCALE if _PROFILE == "nightly" else 1

    def settings(*args, **kw):
        if "max_examples" in kw:
            kw["max_examples"] = int(kw["max_examples"] * _SCALE)
        return _hp_settings(*args, **kw)

except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stand-in for hypothesis.strategies: every strategy builder
        returns None (never drawn from — the test is skipped)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
