"""Soft hypothesis dependency for the test suite.

A bare ``from hypothesis import ...`` fails collection of the whole module
when hypothesis is absent (and module-scope ``pytest.importorskip`` would
skip every test in it, deterministic ones included).  This shim keeps the
deterministic cases runnable everywhere: when hypothesis is missing, only
the ``@given`` property tests are skipped.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stand-in for hypothesis.strategies: every strategy builder
        returns None (never drawn from — the test is skipped)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
