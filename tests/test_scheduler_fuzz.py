"""Scheduler fuzz: randomized Poisson arrival traces, global invariants.

Seeded traces drive the packed streaming scheduler for N ticks (then a
drain) and assert the invariants that must hold for ANY arrival pattern:

* conservation — no request lost or duplicated (every submitted prompt
  comes back exactly once), and the pending gauge closes to zero once
  the arrival rate drops to zero;
* deadline pressure — after any tick, no still-open group's earliest
  deadline lies in the past (an overdue group must have been launched
  that tick, however empty it is);
* NFE accounting — per-completion ``nfe_share`` totals reproduce the
  scheduler's global NFE ledger, and the packed-execution launch ledger
  stays consistent (every launch carries rows; pads only ever on top of
  real rows);
* clique admission — co-grouped completions always satisfy the pairwise
  (tau_min, tau_max] similarity invariant (checked end-to-end here, on
  real text-tower embeddings rather than synthetic vectors);
* launch-policy safety — every invariant above holds under EVERY launch
  policy (the policy chooses *when*, never *whether*), a pad-aware hold
  never leaves an open group that could no longer meet its earliest
  deadline (deadline-safe hold window), and pad_aware never spends more
  NFE than eager on the same trace (holds merge arrivals into fuller
  groups; they cannot split work).
"""
import jax
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.core import grouping
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.scheduler import RequestScheduler
from repro.serving.trunk_cache import TrunkCache

CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)

THEME_WORDS = ["red circle", "blue square", "green triangle"]


def _trace(seed, ticks, rate):
    """Poisson(rate) arrivals per tick from a small theme pool; every
    prompt is unique so conservation is checkable by identity."""
    rng = np.random.RandomState(seed)
    trace, uid = [], 0
    for _ in range(ticks):
        k = rng.poisson(rate)
        wave = []
        for _ in range(k):
            theme = THEME_WORDS[rng.randint(len(THEME_WORDS))]
            wave.append(f"a {theme} variant {uid}")
            uid += 1
        trace.append(wave)
    return trace


@pytest.mark.parametrize("seed,rate,use_cache,deadlines,policy",
                         [(0, 1.5, False, False, "eager"),
                          (1, 2.5, True, True, "eager"),
                          (2, 0.8, False, True, "eager"),
                          (0, 1.5, False, False, "pad_aware"),
                          (1, 2.5, True, True, "pad_aware"),
                          (2, 0.8, False, True, "pad_aware")])
def test_fuzz_invariants(seed, rate, use_cache, deadlines, policy):
    rng = np.random.RandomState(1000 + seed)
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    sched = RequestScheduler(
        CFG, sage, PARAMS, TEXT_PARAMS, TC, group_size=3, slice_steps=2,
        max_wait_ticks=2, packed=True, policy=policy,
        trunk_cache=TrunkCache(tau_trunk=0.9) if use_cache else None)
    ttf = sched._ticks_to_finish()

    trace = _trace(seed, ticks=6, rate=rate)
    submitted, done, t = [], [], 0.0
    for wave in trace:
        t += 1.0
        if wave:
            dl = t + rng.randint(2, 8) if deadlines and rng.rand() < 0.5 \
                else None
            sched.submit(wave, now=t, deadline=dl)
            submitted.extend(wave)
        done.extend(sched.tick(now=t))
        # deadline invariant: anything overdue launched this tick
        for g in sched.open_groups:
            assert g.earliest_deadline() > t, (
                f"overdue group still open at t={t}")
            # pad-aware hold safety: a group held past its eager launch
            # point must still be able to finish before its deadline
            if (policy == "pad_aware"
                    and sched.ticks - g.created_tick
                    >= sched.max_wait_ticks):
                assert g.earliest_deadline() > t + ttf, (
                    f"deadline-unsafe hold at t={t}")
    # zero arrival rate from here on: the queue must fully drain
    done.extend(sched.drain(now=t))
    assert sched.pending == 0
    assert not (sched.arrivals or sched.open_groups or sched.inflight)

    # conservation: each submitted prompt exactly once, none invented
    assert sorted(c.prompt for c in done) == sorted(submitted)
    assert sched.stats["requests"] == len(submitted)
    assert sched.stats["completed"] == len(done)

    # NFE ledger closes: nfe_share was split evenly inside each group, so
    # summing it over completions reproduces the global spend
    assert np.isclose(sum(c.nfe_share for c in done), sched.stats["nfe"])
    if use_cache:
        assert (sched.stats["nfe"] + sched.stats["nfe_saved_cache"]
                <= sched.stats["nfe_independent"] + 1e-6)
    # launch ledger: rows only from real launches, pads a strict subset
    assert sched.stats["launches"] <= sched.ticks * 2 * max(
        1, len(THEME_WORDS))
    assert 0 <= sched.stats["pack_pad_rows"] < sched.stats["pack_rows"] \
        or sched.stats["pack_rows"] == 0
    if done:
        assert sched.stats["launches"] > 0

    # clique admission end-to-end: co-grouped completions are pairwise
    # similar enough under the engine's own embeddings
    by_gid = {}
    for c in done:
        by_gid.setdefault(c.group_id, []).append(c.prompt)
    toks = te.tokenize(submitted, max_len=CFG.cond_len)
    _, pooled = te.encode_text(TEXT_PARAMS, TC, toks)
    emb = {p: np.asarray(v) for p, v in zip(submitted, pooled)}
    for gid, prompts in by_gid.items():
        assert len(prompts) <= sched.group_size
        e = np.stack([emb[p] for p in prompts])
        sim = grouping.similarity_matrix(e)
        for i in range(len(prompts)):
            for j in range(len(prompts)):
                if i != j:
                    assert sim[i, j] > sage.tau_min, (gid, prompts)

    # summary() stays self-consistent on an arbitrary trace
    s = sched.summary()
    assert s["completed"] == len(done)
    assert s["launches"] == sched.stats["launches"]
    assert 0.0 <= s["pad_waste"] < 1.0
    if done:
        assert s["latency_p50"] > 0 and s["latency_p95"] >= s["latency_p50"]


@pytest.mark.parametrize("seed,rate", [(3, 1.5), (4, 2.5)])
def test_fuzz_pad_aware_never_spends_more_nfe(seed, rate):
    """Same trace under both policies: conservation for each, and the
    pad-aware NFE ledger never exceeds eager's — holding can only merge
    arrivals into fuller groups (fewer shared trunks), never split work.
    Launch counts shrink the same way."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    trace = _trace(seed, ticks=6, rate=rate)

    def run(policy):
        sched = RequestScheduler(
            CFG, sage, PARAMS, TEXT_PARAMS, TC, group_size=3,
            slice_steps=2, max_wait_ticks=2, packed=True, policy=policy)
        done, t = [], 0.0
        for wave in trace:
            t += 1.0
            if wave:
                sched.submit(wave, now=t)
            done.extend(sched.tick(now=t))
        done.extend(sched.drain(now=t))
        assert sched.pending == 0
        return sched, done

    se, de = run("eager")
    sp, dp = run("pad_aware")
    submitted = [p for wave in trace for p in wave]
    assert sorted(c.prompt for c in de) == sorted(submitted)
    assert sorted(c.prompt for c in dp) == sorted(submitted)
    assert sp.stats["nfe"] <= se.stats["nfe"]
    assert sp.stats["launches"] <= se.stats["launches"]
    assert sp.summary()["pad_waste"] <= se.summary()["pad_waste"]


def test_fuzz_empty_trace_is_a_noop():
    sage = SageConfig(total_steps=4, share_ratio=0.25, tau_min=0.2)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=3, packed=True)
    for t in range(3):
        assert sched.tick(now=float(t)) == []
    assert sched.pending == 0 and sched.stats["launches"] == 0
    assert sched.summary()["launches_per_tick"] == 0.0


# ---------------------------------------------------------------------------
# overload traces: QoS + shedding invariants when arrival > service
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [5, 6])
def test_fuzz_overload_qos_shedding(seed):
    """Sustained overload (Poisson arrivals above the capped service
    rate for 30+ ticks, mixed QoS): the scheduler must degrade
    *gracefully* —

    * conservation — admitted == completed + shed + in-flight at every
      tick boundary, statuses included, nothing double-counted;
    * interactive p95 stays bounded (admission refuses work it cannot
      serve inside the saturation horizon, so served latencies cannot
      grow with trace length);
    * batch never starves: batch work keeps completing throughout;
    * once arrivals stop, the system drains to empty.
    """
    rng = np.random.RandomState(seed)
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    sched = RequestScheduler(
        CFG, sage, PARAMS, TEXT_PARAMS, TC, group_size=3, slice_steps=2,
        max_wait_ticks=1, packed=True, max_groups_per_tick=1,
        admission="shed", starvation_ticks=4)
    horizon = sched.admission.horizon_ticks
    headroom = sched.admission.interactive_headroom
    ttf = sched._ticks_to_finish()

    overload_ticks = 32
    trace = _trace(seed, ticks=overload_ticks, rate=2.0)  # >> 1 group/tick
    submitted, done, t = [], [], 0.0
    for wave in trace:
        t += 1.0
        if wave:
            qos = ["interactive" if rng.rand() < 0.5 else "batch"
                   for _ in wave]
            dl = t + float(rng.randint(8, 16))
            sched.submit(wave, now=t, deadline=dl, qos=qos)
            submitted.extend(wave)
        done.extend(sched.tick(now=t))
        # conservation at every tick boundary, refusals included
        st = sched.stats
        assert st["requests"] == st["completed"] + st["shed"] \
            + st["shed_faulted"] + st["rejected_expired"] + sched.pending
        assert len(done) == st["requests"] - sched.pending

    # saturation actually happened and shedding engaged
    assert len(submitted) > overload_ticks
    assert sched.stats["shed"] > 0

    # drain-to-empty once the arrival process stops
    while sched.pending and t < 400:
        t += 1.0
        done.extend(sched.tick(now=t))
    assert sched.pending == 0
    assert not (sched.arrivals or sched.open_groups or sched.inflight)

    # every submitted prompt resolved exactly once (served or refused)
    assert sorted(c.prompt for c in done) == sorted(submitted)
    by = {}
    for c in done:
        by.setdefault((c.qos, c.status), []).append(c)
    assert all(c.status in ("ok", "shed", "rejected_expired")
               for c in done)

    # batch no-starvation: batch work completed, not just shed
    assert len(by.get(("batch", "ok"), [])) > 0

    # interactive p95 bounded by the admission horizon: anything served
    # was admitted inside backlog <= horizon * headroom, so its latency
    # is at most that backlog plus its own service time plus bounded
    # starvation interference — independent of trace length
    int_ok = by.get(("interactive", "ok"), [])
    assert len(int_ok) > 0
    bound = horizon * headroom + ttf + sched.starvation_ticks + 2.0
    p95 = float(np.percentile([c.latency for c in int_ok], 95))
    assert p95 <= bound, (p95, bound)

    # summary stays self-consistent under overload
    s = sched.summary()
    assert s["shed"] == sched.stats["shed"]
    assert s["goodput"] <= s["completed"]
    assert s["interactive_completed"] == len(int_ok) + \
        len(by.get(("interactive", "degraded"), []))


def test_fuzz_overload_degrade_mode_serves_everything():
    """Degrade-mode admission under the same pressure: nothing is shed —
    late arrivals are served at draft NFE instead — and the degraded
    population spends fewer NFE per request than the clean one."""
    rng = np.random.RandomState(7)
    sage = SageConfig(total_steps=8, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    sched = RequestScheduler(
        CFG, sage, PARAMS, TEXT_PARAMS, TC, group_size=3, slice_steps=2,
        max_wait_ticks=1, packed=True, max_groups_per_tick=1,
        admission="degrade")
    trace = _trace(8, ticks=12, rate=2.0)
    submitted, done, t = [], [], 0.0
    for wave in trace:
        t += 1.0
        if wave:
            sched.submit(wave, now=t)
            submitted.extend(wave)
        done.extend(sched.tick(now=t))
    while sched.pending and t < 400:
        t += 1.0
        done.extend(sched.tick(now=t))
    assert sched.pending == 0
    assert sorted(c.prompt for c in done) == sorted(submitted)
    assert sched.stats["shed"] == 0
    degraded = [c for c in done if c.status == "degraded"]
    clean = [c for c in done if c.status == "ok"]
    assert degraded and clean
    assert sched.stats["degraded"] == len(degraded)
    # draft NFE: degraded requests run at the draft-tier step budget
    assert all(c.tier == sched.degrade_tier for c in degraded)
    assert (np.mean([c.nfe_share for c in degraded])
            < np.mean([c.nfe_share for c in clean]))


# ---------------------------------------------------------------------------
# cache-heavy traces: tier-ledger balance + LSH-vs-scan NFE parity
# ---------------------------------------------------------------------------

def _run_cached(trace, cache, ledger_probe=None):
    """Drive a trace through a cached scheduler; returns (sched, done)."""
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    sched = RequestScheduler(
        CFG, sage, PARAMS, TEXT_PARAMS, TC, group_size=3, slice_steps=2,
        max_wait_ticks=1, packed=True, trunk_cache=cache)
    done, t = [], 0.0
    for wave in trace:
        t += 1.0
        if wave:
            sched.submit(wave, now=t)
        done.extend(sched.tick(now=t))
        if ledger_probe is not None:
            ledger_probe(cache)
    done.extend(sched.drain(now=t))
    if ledger_probe is not None:
        ledger_probe(cache)
    return sched, done


def _assert_tier_ledger(cache):
    """The tiered bytes ledger must balance at every boundary: the
    incremental counters equal a full recount, per tier and in total."""
    assert cache.bytes == cache.ledger_bytes()
    assert cache.tier_bytes == cache.tier_ledger()
    assert (cache.tier_bytes["hbm"] + cache.tier_bytes["host"]
            == cache.bytes)
    assert cache.tier_bytes["hbm"] >= 0 and cache.tier_bytes["host"] >= 0


@pytest.mark.parametrize("seed,index", [(9, "lsh"), (10, "scan")])
def test_fuzz_cache_tiers_ledger_balances(seed, index):
    """High-repetition themes against a deliberately tiny HBM budget:
    every completed trunk overflows the working set and spills to the
    host tier, and the per-tier bytes ledger must balance after every
    tick — through spills, promotions, overwrites and hits alike.
    Conservation and the NFE ledger hold exactly as in the uncached
    fuzz."""
    # tau=0.99 is tight enough that distinct themes stay distinct
    # entries (a loose tau lets one trunk absorb the whole trace and
    # nothing ever spills), loose enough that repeats still hit
    cache = TrunkCache(tau_trunk=0.99, index=index, max_bytes=1,
                       host_bytes=1 << 20)
    trace = _trace(seed, ticks=8, rate=2.5)
    sched, done = _run_cached(trace, cache,
                              ledger_probe=_assert_tier_ledger)
    submitted = [p for wave in trace for p in wave]
    assert sorted(c.prompt for c in done) == sorted(submitted)
    assert sched.pending == 0
    assert np.isclose(sum(c.nfe_share for c in done), sched.stats["nfe"])
    assert (sched.stats["nfe"] + sched.stats["nfe_saved_cache"]
            <= sched.stats["nfe_independent"] + 1e-6)
    # the tiny HBM budget forced real spill traffic (a 1-byte working
    # set holds at most the newest trunk), and repeated themes hit
    assert cache.stats["spills"] > 0
    assert cache.stats["hits"] > 0
    assert len(cache) <= 1 + cache.stats["spills"]
    s = sched.summary()
    assert s["cache_spills"] == cache.stats["spills"]
    assert s["cache_hbm_bytes"] + s["cache_host_bytes"] == cache.bytes
    assert s["cache_index"] == index


@pytest.mark.parametrize("seed", [11, 12])
def test_fuzz_lsh_vs_scan_nfe_parity(seed):
    """The same trace served through an LSH-indexed cache and the scan
    oracle: when LSH recall is 1.0 (these seeds — repeated themes make
    hits mostly exact-key, and the default LSH parameters recall the
    rest), hit counts match and the completion NFE is identical, request
    by request.  A recall shortfall could only *lose* hits (never invent
    them) — asserting hit-count equality first makes the parity claim
    meaningful rather than vacuous."""
    trace = _trace(seed, ticks=8, rate=2.5)

    def run(index):
        cache = TrunkCache(tau_trunk=0.9, index=index)
        sched, done = _run_cached(trace, cache)
        assert sched.pending == 0
        return sched, cache, done

    s_scan, c_scan, d_scan = run("scan")
    s_lsh, c_lsh, d_lsh = run("lsh")
    assert c_scan.stats["hits"] > 0          # the trace exercises reuse
    # recall 1.0 on this trace: every hit the oracle found, LSH found
    assert c_lsh.stats["hits"] == c_scan.stats["hits"]
    assert c_lsh.stats["exact_hits"] == c_scan.stats["exact_hits"]
    # ... and then completion NFE must be identical, per request
    assert (sorted((c.prompt, c.nfe_share) for c in d_lsh)
            == sorted((c.prompt, c.nfe_share) for c in d_scan))
    assert s_lsh.stats["nfe"] == s_scan.stats["nfe"]
    assert (s_lsh.stats["nfe_saved_cache"]
            == s_scan.stats["nfe_saved_cache"])


# ---------------------------------------------------------------------------
# mixed-geometry traces: shapes x tiers x samplers drawn per request
# ---------------------------------------------------------------------------

HETERO_SHAPES = [(8, 8, 4), (4, 4, 4), (4, 8, 4)]
HETERO_TIERS = ["draft", "standard", "premium"]


@pytest.mark.parametrize("seed,rate,use_cache,mix_samplers",
                         [(20, 1.5, False, False),
                          (21, 2.5, True, True),
                          (22, 2.0, True, False)])
def test_fuzz_hetero_invariants(seed, rate, use_cache, mix_samplers):
    """Every request independently draws its latent shape, quality tier
    and solver.  Invariants for ANY such trace:

    * conservation — each prompt back exactly once, drain to empty;
    * hetero compartments — co-grouped completions share one (shape,
      tier, sampler), and returned image shapes match the request;
    * per-tier NFE ledger — summing ``nfe_share`` by completion tier
      reproduces ``tier_stats``, and the tier/shape rollups close
      against the request counts;
    * no cross-shape or cross-budget cache hits — every trunk-cache
      lookup carries the group's own shape and a cfg_key holding its
      own (sampler, total_steps);
    * pad accounting exact — the global pad/rows ledger equals the sum
      over per-shape buckets (every launch attributed to one bucket).
    """
    rng = np.random.RandomState(3000 + seed)
    cache = TrunkCache(tau_trunk=0.9) if use_cache else None
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    sched = RequestScheduler(
        CFG, sage, PARAMS, TEXT_PARAMS, TC, group_size=3, slice_steps=2,
        max_wait_ticks=2, packed=True, trunk_cache=cache,
        mix_samplers=mix_samplers)

    lookups = []
    if cache is not None:
        orig_lookup = cache.lookup

        def spy(centroid, beta, cfg_key, shape, payload="trunk"):
            lookups.append((cfg_key, tuple(shape)))
            return orig_lookup(centroid, beta, cfg_key, shape,
                               payload=payload)
        cache.lookup = spy

    trace = _trace(seed, ticks=6, rate=rate)
    submitted, axes, done, t = [], {}, [], 0.0
    for wave in trace:
        t += 1.0
        if wave:
            shp = [HETERO_SHAPES[rng.randint(3)] for _ in wave]
            tr = [HETERO_TIERS[rng.randint(3)] for _ in wave]
            smp = [("ddim", "dpmpp")[rng.randint(2)] for _ in wave]
            sched.submit(wave, now=t, shape=shp, tier=tr, sampler=smp)
            submitted.extend(wave)
            for p, a in zip(wave, zip(shp, tr, smp)):
                axes[p] = a
        done.extend(sched.tick(now=t))
    done.extend(sched.drain(now=t))

    # conservation
    assert sched.pending == 0
    assert not (sched.arrivals or sched.open_groups or sched.inflight)
    assert sorted(c.prompt for c in done) == sorted(submitted)

    # hetero compartments + returned geometry
    by_gid = {}
    for c in done:
        by_gid.setdefault(c.group_id, []).append(c)
        shape, tier, _ = axes[c.prompt]
        assert c.tier == tier
        assert tuple(c.image.shape) == shape      # no VAE: raw latents
    for cs in by_gid.values():
        assert len({axes[c.prompt] for c in cs}) == 1

    # per-tier NFE ledger closes
    assert np.isclose(sum(c.nfe_share for c in done), sched.stats["nfe"])
    for tier in HETERO_TIERS:
        share = sum(c.nfe_share for c in done if c.tier == tier)
        ts = sched.tier_stats.get(tier, {"nfe": 0.0, "completed": 0,
                                         "requests": 0})
        assert np.isclose(share, ts["nfe"]), (tier, share, ts)
        assert ts["completed"] == sum(1 for c in done if c.tier == tier)
        assert ts["requests"] == sum(1 for p in submitted
                                     if axes[p][1] == tier)

    # cache lookups never cross shape or budget compartments
    if cache is not None:
        assert lookups, "cached trace never consulted the cache"
        for cfg_key, shape in lookups:
            assert shape in {s for s, _, _ in axes.values()}
            smp, total = cfg_key[2], cfg_key[4]
            assert smp in ("ddim", "dpmpp")
            assert total in {sched.tiers[x] for x in HETERO_TIERS}

    # pad ledger: global == sum over shape buckets, exactly
    ss = sched.shape_stats
    assert sum(b["launches"] for b in ss.values()) \
        == sched.stats["launches"]
    assert sum(b["rows"] for b in ss.values()) == sched.stats["pack_rows"]
    assert sum(b["pad_rows"] for b in ss.values()) \
        == sched.stats["pack_pad_rows"]
    for key, b in ss.items():
        assert 0 <= b["pad_rows"] <= b["rows"]
        assert tuple(int(x) for x in key.split("x")) in set(
            s for s, _, _ in axes.values())

    # summary exposes the hetero rollups consistently
    s = sched.summary()
    for tier, ts in sched.tier_stats.items():
        assert s[f"tier_{tier}_completed"] == ts["completed"]
    for key, b in ss.items():
        assert s[f"shape_{key}_launches"] == b["launches"]
