"""Per-architecture smoke tests: reduced config, one forward/train step and
one prefill+decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import transformer as tfm

ARCHS = [
    "qwen1.5-32b", "mamba2-780m", "phi3-mini-3.8b", "granite-20b",
    "seamless-m4t-large-v2", "llama-3.2-vision-11b", "qwen3-32b",
    "kimi-k2-1t-a32b", "recurrentgemma-2b", "deepseek-v2-lite-16b",
]

B, S = 2, 32


def make_batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, S, cfg.enc_input_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = tfm.forward_train(
        params, cfg, batch["tokens"],
        extras={k: v for k, v in batch.items() if k not in ("tokens", "labels")})
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = tfm.lm_loss(params, cfg, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grad(arch):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(
        lambda p: tfm.lm_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Prefill(S tokens) then decode token S must match full forward at S.

    MoE archs get an ample capacity factor: exact cross-path consistency
    only holds when no token is dropped (drop sets depend on token count,
    which legitimately differs between train and decode batches)."""
    import dataclasses
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    tokens = batch["tokens"]
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}

    full_logits, _ = tfm.forward_train(params, cfg, tokens, extras=extras)

    last, cache = tfm.prefill(params, cfg, tokens[:, :S - 1], extras=extras,
                              max_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(full_logits[:, S - 2], np.float32), rtol=3e-2, atol=3e-2)

    dec, cache = tfm.decode_step(params, cfg, cache, tokens[:, S - 1:S],
                                 jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(dec[:, 0], np.float32),
        np.asarray(full_logits[:, S - 1], np.float32), rtol=3e-2, atol=3e-2)


def test_decode_from_zero_cache():
    """init_cache + N decode steps matches train forward (mamba2 + dense)."""
    for arch in ("mamba2-780m", "phi3-mini-3.8b", "recurrentgemma-2b"):
        cfg = get_config(arch, smoke=True)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
        full_logits, _ = tfm.forward_train(params, cfg, tokens)
        cache = tfm.init_cache(cfg, B, 16)
        for i in range(8):
            dec, cache = tfm.decode_step(params, cfg, cache,
                                         tokens[:, i:i + 1], jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(dec[:, 0], np.float32),
            np.asarray(full_logits[:, 7], np.float32), rtol=3e-2, atol=3e-2)
