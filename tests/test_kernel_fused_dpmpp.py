"""Fused CFG+DPM-Solver++(2M) kernel parity: the Pallas path must match the
jnp cfg_combine + samplers.dpmpp_2m_step composition across guidance scales,
through the history warmup (first two steps), and over full shared_sample
trajectories (acceptance: atol 1e-5 fp32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SageConfig, get_config, replace
from repro.core import samplers
from repro.core.guidance import cfg_combine
from repro.core.schedule import ddim_timesteps, make_schedule
from repro.core.shared_sampling import independent_sample, shared_sample
from repro.kernels import dispatch
from repro.kernels.dpmpp_step.ops import fused_cfg_dpmpp_step
from repro.models import dit

SCHED = make_schedule(1000)
CFG = get_config("sage-dit", smoke=True)


def _rand(key, shape, n=4, dtype=jnp.float32):
    return tuple(jax.random.normal(jax.random.fold_in(key, i), shape, dtype)
                 for i in range(n))


def _ref_step(z, eu, ec, ep, t, t_next, t_prev, w, clip, is_first):
    """The scan body's reference composition from shared_sampling."""
    eps = cfg_combine(eu, ec, w)
    ep = jnp.where(is_first, eps, ep)
    zn = samplers.dpmpp_2m_step(SCHED, z, t, t_next, eps, ep, t_prev,
                                clip_x0=clip)
    return zn, eps


# ---------------------------------------------------------------------------
# single-step parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("guidance", [1.0, 3.0, 7.5, 12.5])
@pytest.mark.parametrize("clip", [0.0, 3.0])
def test_fused_matches_reference_across_guidance(guidance, clip):
    key = jax.random.PRNGKey(hash((guidance, clip)) % 2**31)
    z, eu, ec, ep = _rand(key, (2, 8, 8, 4))
    t, t_next, t_prev = jnp.int32(700), jnp.int32(466), jnp.int32(933)
    ref_z, ref_e = _ref_step(z, eu, ec, ep, t, t_next, t_prev, guidance,
                             clip, False)
    sc = samplers.dpmpp_scalars(SCHED, t, t_next, t_prev)
    out_z, out_e = fused_cfg_dpmpp_step(z, eu, ec, ep, guidance, *sc,
                                        False, clip_x0=clip)
    np.testing.assert_allclose(np.asarray(out_z), np.asarray(ref_z),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(ref_e),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(3, 17, 5, 3), (1, 7, 9, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_odd_shapes_and_dtypes(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    z, eu, ec, ep = _rand(key, shape, dtype=dtype)
    t, t_next, t_prev = jnp.int32(500), jnp.int32(333), jnp.int32(666)
    ref_z, ref_e = _ref_step(z, eu, ec, ep, t, t_next, t_prev, 5.0, 2.0,
                             False)
    sc = samplers.dpmpp_scalars(SCHED, t, t_next, t_prev)
    out_z, out_e = fused_cfg_dpmpp_step(z, eu, ec, ep, 5.0, *sc, False,
                                        clip_x0=2.0)
    assert out_z.dtype == z.dtype and out_e.dtype == z.dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_z, np.float32),
                               np.asarray(ref_z, np.float32),
                               rtol=tol, atol=tol)


def test_history_warmup_first_two_steps():
    """Step 1 (is_first: history term must vanish exactly, even with a
    garbage eps_prev and t_prev == t) feeding step 2 (first real 2M
    extrapolation off step 1's combined eps)."""
    grid = jnp.asarray(ddim_timesteps(SCHED.T, 8))
    key = jax.random.PRNGKey(42)
    z, eu1, ec1, _ = _rand(key, (2, 8, 8, 4))
    eu2, ec2, _, _ = _rand(jax.random.fold_in(key, 9), (2, 8, 8, 4))
    w, clip = 7.5, 3.0

    # --- step 1: i == 0, t_prev aliases t, eps_prev carry is zeros -------
    t, t_next, t_prev = grid[0], grid[1], grid[0]
    ref_z1, ref_e1 = _ref_step(z, eu1, ec1, jnp.zeros_like(z), t, t_next,
                               t_prev, w, clip, True)
    sc = samplers.dpmpp_scalars(SCHED, t, t_next, t_prev)
    out_z1, out_e1 = fused_cfg_dpmpp_step(z, eu1, ec1, jnp.zeros_like(z),
                                          w, *sc, True, clip_x0=clip)
    np.testing.assert_allclose(np.asarray(out_z1), np.asarray(ref_z1),
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.all(jnp.isfinite(out_z1)))

    # --- step 2: first real extrapolation against step 1's carry ---------
    t, t_next, t_prev = grid[1], grid[2], grid[0]
    ref_z2, _ = _ref_step(ref_z1, eu2, ec2, ref_e1, t, t_next, t_prev, w,
                          clip, False)
    sc = samplers.dpmpp_scalars(SCHED, t, t_next, t_prev)
    out_z2, _ = fused_cfg_dpmpp_step(out_z1, eu2, ec2, out_e1, w, *sc,
                                     False, clip_x0=clip)
    np.testing.assert_allclose(np.asarray(out_z2), np.asarray(ref_z2),
                               rtol=1e-5, atol=1e-5)


def test_dispatch_dpmpp_reference_equals_fused():
    key = jax.random.PRNGKey(3)
    z, eu, ec, ep = _rand(key, (2, 6, 6, 4))
    sc = samplers.dpmpp_scalars(SCHED, jnp.int32(500), jnp.int32(333),
                                jnp.int32(666))
    names = ("a_t", "s_t", "a_n", "s_n", "lam", "lam_p", "lam_n")
    kw = dict(zip(names, sc), guidance=5.0, is_first=False, clip_x0=3.0)
    ref_z, ref_e = dispatch.cfg_dpmpp_step(z, eu, ec, ep, impl="reference",
                                           **kw)
    out_z, out_e = dispatch.cfg_dpmpp_step(z, eu, ec, ep, impl="fused",
                                           **kw)
    np.testing.assert_allclose(np.asarray(out_z), np.asarray(ref_z),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(ref_e),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        dispatch.cfg_dpmpp_step(z, eu, ec, ep, impl="magic", **kw)


# ---------------------------------------------------------------------------
# end-to-end: shared_sample / independent_sample fused vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shared_uncond", [False, True])
def test_shared_sample_dpmpp_fused_matches_reference(shared_uncond):
    key = jax.random.PRNGKey(0)
    params = dit.init_params(CFG, key)
    K, N = 2, 3
    cond = jax.random.normal(jax.random.fold_in(key, 1),
                             (K, N, CFG.cond_len, CFG.cond_dim))
    mask = jnp.ones((K, N)).at[1, 2].set(0.0)
    null = jnp.zeros((CFG.cond_len, CFG.cond_dim))
    shape = (CFG.latent_size, CFG.latent_size, CFG.latent_channels)
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=3.0,
                      sampler="dpmpp", shared_uncond_cfg=shared_uncond)

    def run(sg):
        return shared_sample(
            lambda z, t, c: dit.forward(params, CFG, z, t, c),
            SCHED, sg, key, cond, mask, null, shape)

    ref = run(sage)
    out = run(replace(sage, step_impl="fused"))
    assert int(ref["nfe"]) == int(out["nfe"])  # fusion must not change NFE
    np.testing.assert_allclose(np.asarray(out["latents"]),
                               np.asarray(ref["latents"]),
                               rtol=1e-5, atol=1e-5)


def test_independent_sample_dpmpp_fused_matches_reference():
    key = jax.random.PRNGKey(5)
    params = dit.init_params(CFG, key)
    cond = jax.random.normal(jax.random.fold_in(key, 1),
                             (2, CFG.cond_len, CFG.cond_dim))
    null = jnp.zeros((CFG.cond_len, CFG.cond_dim))
    shape = (CFG.latent_size, CFG.latent_size, CFG.latent_channels)
    sage = SageConfig(total_steps=5, guidance_scale=7.5, sampler="dpmpp")

    def run(sg):
        return independent_sample(
            lambda z, t, c: dit.forward(params, CFG, z, t, c),
            SCHED, sg, key, cond, null, shape)

    ref = run(sage)
    out = run(replace(sage, step_impl="fused"))
    np.testing.assert_allclose(np.asarray(out["latents"]),
                               np.asarray(ref["latents"]),
                               rtol=1e-5, atol=1e-5)
