"""End-to-end system behaviour: Alg. 2 training -> Alg. 1 serving through
the engine, with NFE accounting matching the analytic cost model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import OptimConfig, SageConfig, get_config
from repro.core import trainer
from repro.core.grouping import cost_saving
from repro.core.schedule import make_schedule
from repro.data.synthetic import ShapesDataset
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.engine import SageServingEngine


def test_train_then_serve_end_to_end():
    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=6, share_ratio=0.33, guidance_scale=2.0,
                      tau_min=0.2)
    sched = make_schedule(1000)
    opt = OptimConfig(lr=1e-3)

    # --- Alg. 2: a few SAGE training steps -----------------------------
    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(0))
    step = trainer.make_sage_train_step(cfg, sage, sched, opt)
    K, N, H = 2, 3, cfg.latent_size
    batch = {
        "z": jax.random.normal(jax.random.PRNGKey(1), (K, N, H, H, 4)),
        "cond": jax.random.normal(jax.random.PRNGKey(2),
                                  (K, N, cfg.cond_len, cfg.cond_dim)),
        "mask": jnp.ones((K, N)),
    }
    first = last = None
    for i in range(5):
        state, m = step(state, batch, jax.random.PRNGKey(10 + i))
        first = float(m["loss"]) if first is None else first
        last = float(m["loss"])
    assert np.isfinite(last) and last < first

    # --- Alg. 1: serve through the engine -------------------------------
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    engine = SageServingEngine(
        cfg, sage, dit_params=state["params"],
        text_params=te.init_text(jax.random.PRNGKey(3), tc),
        text_cfg=tc, group_size=3)
    _, prompts = ShapesDataset(res=16).batch(0, 9)
    engine.submit(prompts)
    done = engine.step(max_batch=9)
    assert len(done) == 9
    assert all(np.isfinite(c.image).all() for c in done)

    # NFE accounting equals the analytic cost model for the same grouping
    groups = {}
    for c in done:
        groups.setdefault(c.group_id, []).append(c.prompt)
    analytic = cost_saving([v for v in groups.values()], sage.total_steps,
                           sage.branch_point)
    assert engine.stats["nfe"] == analytic["nfe_shared"]
    assert engine.stats["nfe_independent"] == analytic["nfe_independent"]
