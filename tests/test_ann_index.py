"""Differential correctness suite for the trunk cache's ANN index.

The safety argument for ``index="lsh"`` is structural — candidates are
re-verified against the exact ``tau_trunk`` cosine, so the approximate
index can only *miss*, never accept what the exact scan would reject.
This suite checks that argument differentially against the
``index="scan"`` oracle:

* **false-accept rate = 0** (property-fuzzed): any hit the LSH cache
  returns clears the exact cosine threshold AND would also be a hit for
  the scan oracle on the same population — for every random population,
  tau, dim and query stream hypothesis can draw;
* **recall ≥ 0.95** (measured): on seeded populations with
  near-duplicate queries, the LSH cache hits at least 95% as often as
  the scan oracle at every supported ``tau_trunk`` ∈ {0.90, 0.95, 0.99};
* bucket-rehash and empty-index edge cases on the raw
  :class:`~repro.serving.ann_index.LshIndex`.

Everything runs the *public* cache interface where possible, so the
properties pin the deployed lookup path, not an index abstraction.
"""
import numpy as np
import pytest

from repro.serving.ann_index import LshIndex, ScanIndex, make_index
from repro.serving.trunk_cache import TrunkCache, TrunkEntry

from hypothesis_compat import given, settings, st

TAUS = (0.90, 0.95, 0.99)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _unit_rows(rng: np.random.RandomState, n: int, dim: int) -> np.ndarray:
    v = rng.randn(n, dim).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _entry(centroid, tag: float, beta=0.5, cfg="cfg") -> TrunkEntry:
    z = np.full((1, 2, 2, 1), tag, np.float32)
    return TrunkEntry(z=z, eps_prev=None, step_idx=2, beta_bucket=beta,
                      rng_fold=0, centroid=np.asarray(centroid, np.float32),
                      cfg_key=cfg)


def _twin_caches(tau: float, **lsh_kw):
    """A scan-oracle cache and an LSH cache with identical parameters."""
    scan = TrunkCache(tau_trunk=tau, index="scan")
    lsh = TrunkCache(tau_trunk=tau, index=LshIndex(**lsh_kw))
    return scan, lsh

SHAPE = (1, 2, 2, 1)


def _populate(caches, pop):
    for i, v in enumerate(pop):
        for c in caches:
            c.insert(_entry(v, tag=float(i)), shape=SHAPE)


def _near_queries(rng, pop, tau, n_queries):
    """Perturbed copies of stored centroids whose exact cosine to their
    source stays >= tau (rejection-sampled, so the scan oracle is
    guaranteed a hit for every query)."""
    dim = pop.shape[1]
    # per-component noise sized so the expected cosine sits just above
    # tau: |noise| ~ s*sqrt(dim) and cos ~ 1/sqrt(1+s^2 dim), so
    # s^2 dim <~ 2(1-tau) keeps the acceptance rate high at every dim
    scale = 0.5 * np.sqrt(2.0 * (1.0 - tau) / dim)
    out = []
    while len(out) < n_queries:
        i = rng.randint(len(pop))
        q = pop[i] + scale * rng.randn(dim).astype(np.float32)
        q /= np.linalg.norm(q)
        if float(pop[i] @ q) >= tau:
            out.append(q)
    return np.asarray(out, np.float32)


# ---------------------------------------------------------------------------
# false accepts are impossible by construction (differential property)
# ---------------------------------------------------------------------------

def check_no_false_accepts(seed: int, dim: int, n: int, tau: float) -> None:
    """ANY hit the LSH cache returns (a) clears the exact tau_trunk
    cosine and (b) is a hit the scan oracle confirms with at-least-equal
    similarity."""
    rng = np.random.RandomState(seed)
    scan, lsh = _twin_caches(tau)
    pop = _unit_rows(rng, n, dim)
    _populate((scan, lsh), pop)
    # half adversarially-near queries, half independent randoms
    queries = np.concatenate(
        [_near_queries(rng, pop, tau, 6), _unit_rows(rng, 6, dim)])
    for q in queries:
        got_l = lsh.lookup(q, 0.5, "cfg", SHAPE)
        got_s = scan.lookup(q, 0.5, "cfg", SHAPE)
        if got_l is not None:
            sim_l = float(got_l.centroid @ q)
            assert sim_l >= tau, "LSH returned a below-threshold hit"
            assert got_s is not None, \
                "LSH hit where the exact scan oracle misses"
            assert float(got_s.centroid @ q) >= sim_l - 1e-6, \
                "scan oracle found a worse best-match than LSH"


def check_candidates_resident(seed: int) -> None:
    """Index candidates always reference resident keys, even across
    overwrites and removals (no dangling-key false accepts)."""
    rng = np.random.RandomState(seed)
    idx = LshIndex(n_tables=4, n_bits=4, seed=1)
    pop = _unit_rows(rng, 12, 8)
    keys = [("k", i) for i in range(len(pop))]
    for k, v in zip(keys, pop):
        idx.add(k, v)
    for k in keys[::3]:
        idx.discard(k)
    alive = set(keys) - set(keys[::3])
    for q in _unit_rows(rng, 8, 8):
        assert set(idx.candidates(q)) <= alive


@given(seed=st.integers(0, 10_000), dim=st.sampled_from([4, 16, 48]),
       n=st.integers(1, 24), tau=st.sampled_from(TAUS))
@settings(max_examples=40, deadline=None)
def test_lsh_never_false_accepts(seed, dim, n, tau):
    check_no_false_accepts(seed, dim, n, tau)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("tau", TAUS)
def test_lsh_never_false_accepts_deterministic(seed, tau):
    """Deterministic twin of the property case: always runs, hypothesis
    or not."""
    check_no_false_accepts(seed * 101 + 5, dim=16, n=20, tau=tau)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_lsh_candidates_are_resident(seed):
    check_candidates_resident(seed)


@pytest.mark.parametrize("seed", [0, 7, 23])
def test_lsh_candidates_are_resident_deterministic(seed):
    check_candidates_resident(seed)


# ---------------------------------------------------------------------------
# measured recall vs the scan oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", TAUS)
def test_lsh_recall_vs_scan_oracle(tau):
    """At default LSH parameters the cache-level recall (LSH hits /
    scan-oracle hits on identical populations and query streams) clears
    0.95 for every supported tau_trunk."""
    rng = np.random.RandomState(7)
    scan, lsh = _twin_caches(tau)
    pop = _unit_rows(rng, 256, 64)
    _populate((scan, lsh), pop)
    queries = _near_queries(rng, pop, tau, 200)
    hits_scan = hits_lsh = 0
    for q in queries:
        hits_scan += scan.lookup(q, 0.5, "cfg", SHAPE) is not None
        hits_lsh += lsh.lookup(q, 0.5, "cfg", SHAPE) is not None
    assert hits_scan == len(queries)     # oracle hits by construction
    recall = hits_lsh / hits_scan
    assert recall >= 0.95, f"recall {recall:.3f} < 0.95 at tau={tau}"


def test_lsh_narrows_candidates():
    """The point of the index: the similarity search touches a small
    fraction of a large population (sub-linear candidate sets), while
    still recalling near-duplicates."""
    rng = np.random.RandomState(3)
    idx = LshIndex()
    pop = _unit_rows(rng, 512, 64)
    for i, v in enumerate(pop):
        idx.add(("k", i), v)
    scale = 0.5 * np.sqrt(2.0 * (1.0 - 0.90) / 64)
    found = 0
    for i in range(100):
        q = pop[i] + scale * rng.randn(64).astype(np.float32)
        q /= np.linalg.norm(q)
        if float(pop[i] @ q) < 0.90:     # drifted below the tau regime
            found += 1                   # (not an index miss; skip)
            continue
        found += ("k", i) in idx.candidates(q)
    assert found >= 95
    assert idx.mean_candidates < 0.5 * len(pop)


# ---------------------------------------------------------------------------
# bucket rehash + empty-index edge cases
# ---------------------------------------------------------------------------

def test_rebuild_preserves_buckets():
    rng = np.random.RandomState(11)
    idx = LshIndex(n_tables=6, n_bits=5, seed=2)
    pop = _unit_rows(rng, 64, 16)
    for i, v in enumerate(pop):
        idx.add(("k", i), v)
    queries = _unit_rows(rng, 16, 16)
    before = [idx.candidates(q) for q in queries]
    idx.rebuild()
    after = [idx.candidates(q) for q in queries]
    assert before == after               # same planes -> same buckets
    assert idx.stats["rehashes"] == 1
    assert len(idx) == len(pop)


def test_rebuild_after_discards_drops_dead_keys():
    rng = np.random.RandomState(12)
    idx = LshIndex(n_tables=4, n_bits=3, seed=0)
    pop = _unit_rows(rng, 32, 8)
    for i, v in enumerate(pop):
        idx.add(("k", i), v)
    for i in range(0, 32, 2):
        idx.discard(("k", i))
    idx.rebuild()
    assert len(idx) == 16
    for q in _unit_rows(rng, 8, 8):
        assert all(k[1] % 2 == 1 for k in idx.candidates(q))


def test_readd_rehashes_new_centroid():
    """Re-adding a key with a different centroid must re-bucket it — a
    stale signature would leave candidates pointing at the wrong
    neighbourhood."""
    idx = LshIndex(n_tables=8, n_bits=6, seed=0)
    a = np.zeros(16, np.float32); a[0] = 1.0
    b = np.zeros(16, np.float32); b[1] = -1.0
    idx.add(("k",), a)
    idx.add(("k",), b)                   # overwrite with opposite vector
    assert len(idx) == 1
    assert ("k",) in idx.candidates(b)


def test_empty_index_and_cache():
    idx = LshIndex()
    assert idx.candidates(np.ones(8, np.float32)) == []
    assert len(idx) == 0
    idx.rebuild()                        # no-op on empty
    cache = TrunkCache(index="lsh")
    assert cache.lookup(np.ones(8), 0.5, "cfg", SHAPE) is None
    assert cache.stats["misses"] == 1


def test_make_index_resolution():
    assert isinstance(make_index("scan"), ScanIndex)
    assert isinstance(make_index("lsh"), LshIndex)
    assert isinstance(make_index(None), ScanIndex)
    inst = LshIndex(n_tables=2, n_bits=2)
    assert make_index(inst) is inst
    with pytest.raises(ValueError):
        make_index("ivf")


def test_dim_isolation():
    """Centroids of different embedding dims can never collide in a
    bucket (bucket keys carry the dim)."""
    idx = LshIndex(n_tables=2, n_bits=2, seed=0)
    idx.add(("a",), np.ones(8, np.float32) / np.sqrt(8.0))
    idx.add(("b",), np.ones(16, np.float32) / 4.0)
    assert idx.candidates(np.ones(8, np.float32) / np.sqrt(8.0)) == [("a",)]
    assert idx.candidates(np.ones(16, np.float32) / 4.0) == [("b",)]
