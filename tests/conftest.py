"""Shared pytest fixtures.

``jax.clear_caches()`` between modules bounds the compiled-executable
state a single tier-1 process accumulates.  Every test module builds
fresh engines/schedulers (each with their own jit caches), so by the
time the suite's later modules compile, hundreds of executables from
earlier modules are still resident; past a threshold that deterministically
segfaults XLA's CPU backend inside ``backend_compile`` (observed on the
1-vCPU CI image once the suite grew past ~300 tests).  Per-module
clearing costs a few cross-module recompiles and keeps the process
bounded no matter how large the suite grows.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
