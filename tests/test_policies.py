"""Directed tests for the admission/launch policy layer.

Policy units run against fake groups and a hand-built
:class:`~repro.serving.policies.LaunchContext` (no scheduler, no
denoiser); the integration cases drive a real
:class:`~repro.serving.scheduler.RequestScheduler` on tiny traces and pin
the behaviors the policies exist for: hold-window expiry launching before
a deadline, popularity admission storing on the Nth demand hit,
cold-first eviction, and ``run_batch`` issuing one stacked launch per
phase across beta buckets.
"""
import jax
import numpy as np
import pytest

from repro.config import SageConfig, get_config
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.packing import PackKey
from repro.serving.policies import (AdmitAll, LaunchContext,
                                    PadAwarePolicy, PopularityAdmission,
                                    make_cache_admission, make_launch_policy)
from repro.serving.scheduler import RequestScheduler
from repro.serving.trunk_cache import TrunkCache, TrunkEntry

CFG = get_config("sage-dit", smoke=True)
PARAMS = dit.init_params(CFG, jax.random.PRNGKey(0))
TC = te.text_cfg(dim=CFG.cond_dim, layers=2)
TEXT_PARAMS = te.init_text(jax.random.PRNGKey(1), TC)


# ---------------------------------------------------------------------------
# launch-policy units (fake groups, hand-built context)
# ---------------------------------------------------------------------------

class _G:
    def __init__(self, n_members, created_tick, deadline=None, sig="a"):
        self.members = list(range(n_members))
        self.created_tick = created_tick
        self._deadline = deadline
        self.sig = sig

    def earliest_deadline(self):
        return float("inf") if self._deadline is None else self._deadline


def _ctx(tick=10, now=10.0, inflight=(), ttf=3, max_wait=2, slack=0.0):
    sigs = frozenset(PackKey("shared", "ddim", (8, 8, 4), s)
                     for s in inflight)
    return LaunchContext(
        now=now, tick=tick, group_size=4, max_wait_ticks=max_wait,
        deadline_slack=slack, ticks_to_finish=ttf,
        inflight_signatures=sigs,
        signature_of=lambda g: PackKey("shared", "ddim", (8, 8, 4), g.sig))


def test_eager_launches_full_waited_urgent():
    pol = make_launch_policy("eager")
    assert pol.name == "eager"
    full = _G(4, created_tick=10)
    waited = _G(2, created_tick=8)
    urgent = _G(1, created_tick=10, deadline=10.0)
    fresh = _G(1, created_tick=10)
    assert pol.launches([full, waited, urgent, fresh], _ctx()) \
        == [full, waited, urgent]


def test_pad_aware_holds_subfull_within_window():
    """A waited sub-full group with no deadline pressure and no matching
    in-flight bucket is held — launched only once the hold expires."""
    pol = PadAwarePolicy(hold_ticks=2)
    g = _G(2, created_tick=0)
    assert pol.launches([g], _ctx(tick=2)) == []     # eager would launch
    assert pol.launches([g], _ctx(tick=3)) == []     # still held
    assert pol.launches([g], _ctx(tick=4)) == [g]    # hold expired
    # full groups are never held
    full = _G(4, created_tick=2)
    assert pol.launches([full], _ctx(tick=2)) == [full]


def test_pad_aware_deadline_unsafe_hold_releases():
    """Holding must stop while the group can still finish: a deadline
    inside now + slack + ticks_to_finish forces the launch even though
    the hold window has ticks left."""
    pol = PadAwarePolicy(hold_ticks=5)
    safe = _G(2, created_tick=0, deadline=20.0)
    tight = _G(2, created_tick=0, deadline=12.9)     # 10 + 3 ttf < 13
    assert pol.launches([safe, tight], _ctx(tick=2, now=10.0, ttf=3)) \
        == [tight]
    # and with a comfortable deadline the group is held like any other
    assert pol.launches([safe], _ctx(tick=2, now=10.0, ttf=3)) == []


def test_pad_aware_fills_existing_buckets_first():
    """A held group whose would-be PackKey matches an in-flight bucket
    rides that launch for free — released immediately, ordered after the
    never-held (full/urgent) groups and before hold expiries."""
    pol = PadAwarePolicy(hold_ticks=3)
    full = _G(4, created_tick=2)
    fills = _G(2, created_tick=0, sig=2)
    held = _G(2, created_tick=0, sig=9)
    expired = _G(3, created_tick=-3, sig=9)
    out = pol.launches([held, expired, fills, full],
                       _ctx(tick=2, inflight=(2,)))
    assert out == [full, fills, expired]


def test_make_launch_policy_rejects_unknown():
    with pytest.raises(ValueError, match="unknown launch policy"):
        make_launch_policy("nope")
    pol = PadAwarePolicy(hold_ticks=0)
    assert make_launch_policy(pol) is pol
    with pytest.raises(ValueError):
        PadAwarePolicy(hold_ticks=-1)


# ---------------------------------------------------------------------------
# cache-admission units
# ---------------------------------------------------------------------------

def test_popularity_admits_on_nth_lookup():
    adm = PopularityAdmission(threshold=3)
    assert not adm.admit("k")
    adm.on_lookup("k")
    adm.on_lookup("k")
    assert not adm.admit("k")                 # 2 < 3
    adm.on_lookup("k")
    assert adm.admit("k")                     # 3rd demand hit admits
    assert not adm.admit("other")


def test_popularity_victim_is_coldest_then_lru():
    adm = PopularityAdmission(threshold=1)
    for key, n in (("hot", 3), ("warm", 2), ("cold", 1), ("cold2", 1)):
        for _ in range(n):
            adm.on_lookup(key)
    # keys iterate LRU -> MRU; the coldest wins, ties stay LRU-first
    assert adm.victim(["hot", "cold", "warm", "cold2"]) == "cold"
    assert adm.victim(["hot", "warm"]) == "warm"
    assert AdmitAll().victim(["a", "b"]) == "a"   # plain LRU
    assert AdmitAll().victim([]) is None


def test_popularity_counter_state_is_bounded():
    adm = PopularityAdmission(threshold=1, max_keys=8)
    adm.on_lookup("hot")
    adm.on_lookup("hot")
    for i in range(9):
        adm.on_lookup(("one-hit", i))
    assert len(adm.counts) <= 8
    assert adm.counts.get("hot") == 2         # pruning drops coldest half


def test_make_cache_admission_rejects_unknown():
    with pytest.raises(ValueError, match="unknown cache admission"):
        make_cache_admission("nope")
    assert make_cache_admission(None).name == "always"
    assert make_cache_admission("popularity", threshold=5).threshold == 5
    with pytest.raises(ValueError):
        PopularityAdmission(threshold=0)


# ---------------------------------------------------------------------------
# trunk-cache integration: admission gating + policy-visible accounting
# ---------------------------------------------------------------------------

def _entry(centroid, fill=0.0, shape=(1, 4, 4, 3)):
    z = np.full(shape, fill, np.float32)
    return TrunkEntry(z=z, eps_prev=np.zeros_like(z), step_idx=2,
                      beta_bucket=0.3, rng_fold=0,
                      centroid=np.asarray(centroid, np.float32),
                      cfg_key=("k",))


def test_cache_popularity_gates_insert_and_counts_rejects():
    tc = TrunkCache(tau_trunk=0.9, admission="popularity")
    c = [1.0, 0.0, 0.0]
    assert tc.lookup(c, 0.3, ("k",), (1, 4, 4, 3)) is None   # demand 1
    assert not tc.insert(_entry(c), shape=(1, 4, 4, 3))      # 1 < 2
    assert tc.stats["admission_rejects"] == 1 and len(tc) == 0
    assert tc.lookup(c, 0.3, ("k",), (1, 4, 4, 3)) is None   # demand 2
    assert tc.insert(_entry(c, fill=2.0), shape=(1, 4, 4, 3))
    hit = tc.lookup(c, 0.3, ("k",), (1, 4, 4, 3))
    assert hit is not None and float(hit.z[0, 0, 0, 0]) == 2.0
    assert tc.stats["hits"] == 1 and tc.stats["admission_rejects"] == 1


def test_cache_exact_hit_feeds_popularity_counter():
    """The satellite fix: the exact-key fast path must tick the demand
    counter too, so repeated exact-theme hits keep their entry hot."""
    tc = TrunkCache(tau_trunk=0.9, admission="popularity")
    c = [0.0, 1.0, 0.0]
    key = tc._quant_key(np.asarray(c, np.float32), 0.3, ("k",),
                        (1, 4, 4, 3))
    tc.admission.counts[key] = 2                  # pre-warmed to admit
    assert tc.insert(_entry(c), shape=(1, 4, 4, 3))
    for i in range(3):                            # exact-key hits
        assert tc.lookup(c, 0.3, ("k",), (1, 4, 4, 3)) is not None
        assert tc.admission.counts[key] == 3 + i
    assert tc.stats["exact_hits"] == 3


def test_cache_evicts_cold_entries_first():
    """Under byte pressure the popularity victim is the coldest stored
    key, not the least recently used one."""
    shape = (1, 4, 4, 3)
    nbytes = _entry([1, 0, 0]).nbytes
    tc = TrunkCache(tau_trunk=0.9, max_bytes=2 * nbytes,
                    admission=PopularityAdmission(threshold=1))
    hot, cold, new = [1.0, 0, 0], [0, 1.0, 0], [0, 0, 1.0]
    for c, n in ((hot, 3), (cold, 1), (new, 1)):
        for _ in range(n):
            tc.lookup(c, 0.3, ("k",), shape)
    assert tc.insert(_entry(hot), shape=shape)
    assert tc.insert(_entry(cold), shape=shape)
    # LRU would evict `hot` (inserted first, not touched since); the
    # cold-first victim must be `cold`
    assert tc.insert(_entry(new), shape=shape)
    assert tc.stats["evictions"] == 1
    assert tc.lookup(hot, 0.3, ("k",), shape) is not None
    assert tc.lookup(new, 0.3, ("k",), shape) is not None
    assert tc.lookup(cold, 0.3, ("k",), shape) is None
    assert tc.ledger_bytes() == tc.bytes


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------

def _sched(policy, **kw):
    sage = SageConfig(total_steps=4, share_ratio=0.25, guidance_scale=2.0,
                      tau_min=0.2)
    kw.setdefault("group_size", 3)
    kw.setdefault("slice_steps", 2)
    kw.setdefault("max_wait_ticks", 1)
    return RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                            packed=True, policy=policy, seed=0, **kw)


def test_hold_window_expiry_forces_launch_before_deadline():
    """A held singleton with a deadline launches as soon as holding one
    more tick could miss it — before the hold window is exhausted — and
    completes in time."""
    sched = _sched(PadAwarePolicy(hold_ticks=50))
    ttf = sched._ticks_to_finish()
    deadline = 3.0 + ttf + 1.0
    sched.submit(["a lone red circle"], now=1.0, deadline=deadline)
    done, t = [], 1.0
    launched_at = None
    while not done and t < 30.0:
        done.extend(sched.tick(now=t))
        if launched_at is None and sched.inflight:
            launched_at = t
        t += 1.0
    assert done, "held request never completed"
    # launched exactly when the deadline-safety margin ran out, well
    # before the 50-tick hold budget
    assert launched_at is not None and launched_at <= deadline - ttf + 1.0
    assert launched_at + ttf <= deadline + 1e-9
    assert done[0].latency <= deadline - 1.0


def test_pad_aware_fills_group_and_reduces_pad_waste():
    """Staggered theme-mates: eager launches a sub-full group and the
    stragglers open a second one; pad_aware holds, absorbs them into one
    full group — less pad waste, fewer launches, no extra NFE."""
    base = "a small red circle on a blue background"
    waves = [[base, base], [], [base]]        # 2 arrive, gap, 1 straggler

    def run(policy):
        sched = _sched(policy)
        done, t = [], 0.0
        for w in waves:
            t += 1.0
            if w:
                sched.submit(w, now=t)
            done.extend(sched.tick(now=t))
        while sched.pending:
            t += 1.0
            done.extend(sched.tick(now=t))
        return sched, done

    se, de = run("eager")
    sp, dp = run("pad_aware")
    assert sorted(c.prompt for c in dp) == sorted(c.prompt for c in de)
    assert len({c.group_id for c in dp}) == 1     # held group absorbed all
    assert len({c.group_id for c in de}) == 2     # eager split the theme
    assert sp.stats["nfe"] <= se.stats["nfe"]
    assert sp.stats["launches"] < se.stats["launches"]
    assert sp.summary()["pad_waste"] < se.summary()["pad_waste"]


def test_run_batch_single_launch_per_phase_across_beta_buckets():
    """The sync path packs beta buckets: two cliques in different
    share-ratio buckets but with aligned phase lengths drain in exactly
    one stacked shared launch + one stacked branch launch (the old path
    paid one launch per phase per bucket)."""
    sage = SageConfig(total_steps=6, share_ratio=0.3, guidance_scale=2.0,
                      tau_min=0.5, adaptive_branch=True)
    sched = RequestScheduler(CFG, sage, PARAMS, TEXT_PARAMS, TC,
                             group_size=4, branch_buckets=(0.2, 0.3, 0.4))
    pooled = np.array([[1.0, 0.0], [0.6, 0.8], [0.0, -1.0]], np.float32)
    conds = np.asarray(jax.random.normal(
        jax.random.PRNGKey(3), (3, CFG.cond_len, CFG.cond_dim)))
    sched._embed = lambda prompts: (conds[:len(prompts)],
                                    pooled[:len(prompts)])
    done = sched.run_batch(["p0", "p1", "p2"], adaptive=True)
    assert len(done) == 3
    # buckets 0.3 (pair) and 0.4 (singleton) both split to n_shared=2 at
    # T=6, so the aligned drain is 2 launches total; NFE is per-bucket
    expect_nfe = (2 * 1 * 2 + 2 * 2 * 4) + (2 * 1 * 2 + 2 * 1 * 4)
    assert sched.stats["nfe"] == expect_nfe
    assert sched.stats["launches"] == 2
    assert sched.stats["pack_rows"] == 2 + 8      # shared K=2, branch 2*4
    assert sched.stats["pack_pad_rows"] == 2 + 3  # pair pads 2, single 3


def test_run_batch_does_not_age_streaming_groups():
    """A sync drain must not advance the tick clock: wait counters of
    open streaming groups are measured in ticks, and a run_batch call in
    between must not push them past max_wait into a padded launch."""
    sched = _sched("eager", max_wait_ticks=3)
    base = "a small red circle on a blue background"
    sched.submit([base], now=1.0)
    sched.tick(now=1.0)
    assert len(sched.open_groups) == 1            # waiting, wait=0
    ticks_before = sched.ticks
    sched.run_batch([base, base])
    assert sched.ticks == ticks_before            # drain left the clock
    assert len(sched.open_groups) == 1            # group not aged out
    sched.tick(now=2.0)
    assert len(sched.open_groups) == 1            # wait=1 < max_wait=3
    done = sched.drain(now=3.0)
    assert [c.prompt for c in done] == [base]


def test_run_batch_ignores_trunk_cache():
    """The synchronous path is documented cache-free: neither lookups nor
    stores may touch an attached trunk cache."""
    cache = TrunkCache(tau_trunk=0.9)
    sched = _sched("eager", trunk_cache=cache)
    base = "a small red circle on a blue background"
    done = sched.run_batch([base, base, base])
    assert len(done) == 3
    assert len(cache) == 0
    assert cache.stats["hits"] == cache.stats["misses"] == 0
    assert sched.trunk_cache is cache             # restored after drain
