"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import OptimConfig


def make_lr_schedule(cfg: OptimConfig, total_steps: int):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(cfg.warmup, 1))
        if cfg.schedule == "cosine":
            frac = jnp.clip((s - cfg.warmup) / max(total_steps - cfg.warmup, 1),
                            0.0, 1.0)
            base = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            base = 1.0
        return cfg.lr * warm * base
    return lr
