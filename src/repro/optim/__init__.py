from repro.optim.optimizers import (adamw, adafactor, make_optimizer,
                                    clip_by_global_norm)
from repro.optim.schedules import make_lr_schedule
