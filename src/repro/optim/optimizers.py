"""Functional optimizers (optax-style, dependency-free).

An optimizer is a pair (init, update):
    state = init(params)
    updates, state = update(grads, state, params, lr)
``apply_updates`` adds updates (already scaled by -lr) to params.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimConfig


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": c}

    return Optimizer(init, update)


def adafactor(eps=1e-30, decay=0.8, clip_threshold=1.0) -> Optimizer:
    """Factored second-moment optimizer — the memory-lean option for the
    biggest training configs (state is O(rows+cols) for matrices vs Adam's
    2x full)."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def per_leaf(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"s": jax.tree.map(per_leaf, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** (-decay)

        def per_leaf(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                                  eps))
                upd = gf / jnp.maximum(denom, eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = gf / jnp.sqrt(v + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * upd).astype(p.dtype), ns

        flat_u = jax.tree.map(per_leaf, grads, state["s"], params,
                              is_leaf=lambda x: isinstance(x, jax.Array))
        updates = jax.tree.map(lambda t: t[0], flat_u,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree.map(lambda t: t[1], flat_u,
                             is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"s": new_s, "count": c}

    return Optimizer(init, update)


def make_optimizer(cfg: OptimConfig) -> Optimizer:
    if cfg.kind == "adamw":
        return adamw(cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay)
    if cfg.kind == "adafactor":
        return adafactor()
    raise ValueError(cfg.kind)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
