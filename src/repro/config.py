"""Config system for the repro framework.

Frozen dataclasses + a registry keyed by arch id.  Every assigned
architecture gets a module in ``repro.configs`` that registers its exact
full-size config plus a reduced ``smoke`` variant used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

ATTN_GQA = "gqa"          # grouped-query attention (covers MHA/MQA)
ATTN_MLA = "mla"          # DeepSeek multi-head latent attention

MLP_SWIGLU = "swiglu"
MLP_GELU = "gelu"

# per-layer mixer kinds used by hybrid / vlm patterns
MIX_ATTN = "attn"
MIX_LOCAL_ATTN = "local_attn"
MIX_RGLRU = "rglru"
MIX_SSM = "ssm"
MIX_CROSS_ATTN = "cross_attn"   # self-attn layer followed by cross-attn block


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    # layers < first_moe_layer use a dense MLP of width d_ff_dense
    first_moe_layer: int = 0
    d_ff_dense: int = 0
    router_aux_coef: float = 0.01
    # "dense_onehot" einsum dispatch (dry-run friendly) or "all_to_all"
    dispatch: str = "dense_onehot"
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 -> d_model
    conv_kernel: int = 4
    block_width: int = 256        # diagonal-block input gates


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | dit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0             # 0 -> d_model // n_heads
    attn_kind: str = ATTN_GQA
    mlp_kind: str = MLP_SWIGLU
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    # sliding-window size used by local-attn layers and by the
    # window-cache serving variant that makes long_500k sub-quadratic.
    window: int = 4096

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # hybrid / vlm layer pattern: repeated super-block of mixer kinds.
    # n_layers = len(pattern) * n_blocks + len(remainder)
    pattern: Tuple[str, ...] = ()
    remainder: Tuple[str, ...] = ()

    # encoder-decoder (audio): encoder layer count; frontend supplies
    # precomputed frame embeddings of dim enc_input_dim (stub per brief).
    enc_layers: int = 0
    enc_input_dim: int = 0

    # vlm: cross-attn kv comes from precomputed patch embeddings
    # (n_image_tokens, vision_dim) projected to d_model (frontend stub).
    n_image_tokens: int = 0
    vision_dim: int = 0

    # dit (diffusion backbone)
    latent_size: int = 0          # latent H=W
    latent_channels: int = 0
    patch: int = 2
    cond_dim: int = 0             # text-embedding dim fed to cross-attn
    cond_len: int = 0

    dtype: str = "bfloat16"       # compute dtype
    param_dtype: str = "float32"

    # attention implementation for full-sequence paths (kernels.dispatch):
    # "naive" materialises (Sq, Sk) scores; "chunked" is the online-softmax
    # scan (kernels/flash_attention twin) — the §Perf memory-term variant;
    # "pallas" runs the flash-attention TPU kernel, incl. causal sliding
    # windows (K-index-map variant) and head_dim <= 256 (two-lane-tile D);
    # only head_dim > 256 / non-causal windows fall back to chunked.
    attn_impl: str = "naive"
    attn_block: int = 1024        # chunked-attention key-block size
    # Pallas interpret-mode plumbing: "auto" interprets off-TPU and
    # compiles on TPU; "on"/"off" force it; REPRO_KERNEL_INTERPRET=on|off
    # env var overrides everything (see kernels.dispatch.resolve_interpret).
    kernel_interpret: str = "auto"

    # ---- derived helpers -------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Full per-layer mixer list for non-uniform families."""
        if not self.pattern:
            return tuple([MIX_ATTN] * self.n_layers)
        n_blocks = (self.n_layers - len(self.remainder)) // len(self.pattern)
        kinds = tuple(self.pattern) * n_blocks + tuple(self.remainder)
        assert len(kinds) == self.n_layers, (len(kinds), self.n_layers)
        return kinds

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        kinds = self.layer_kinds()
        total = self.vocab * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab * d                 # lm head
        for i, kind in enumerate(kinds):
            total += 2 * d                          # norms
            if kind in (MIX_ATTN, MIX_LOCAL_ATTN, MIX_CROSS_ATTN):
                if self.attn_kind == ATTN_MLA and self.mla is not None:
                    m = self.mla
                    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * n_q * qd                        # W_q
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                    total += n_q * m.v_head_dim * d              # W_o
                else:
                    total += d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
                    if self.qkv_bias:
                        total += (n_q + 2 * n_kv) * hd
                if kind == MIX_CROSS_ATTN:           # extra cross block
                    total += d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d + d
            elif kind == MIX_RGLRU:
                w = (self.rglru.lru_width or d) if self.rglru else d
                total += 2 * d * w + w * d + 3 * w   # gates approx
            elif kind == MIX_SSM:
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state) + d_in * d
            # mlp
            if self.moe is not None and i >= self.moe.first_moe_layer:
                m = self.moe
                e = m.n_routed + m.n_shared
                total += e * 3 * d * m.d_ff_expert + d * m.n_routed
            else:
                ff = self.moe.d_ff_dense if (self.moe and self.moe.d_ff_dense) else self.d_ff
                mult = 3 if self.mlp_kind == MLP_SWIGLU else 2
                total += mult * d * ff
        # encoder stack (shares the dense layer shape)
        if self.family == "encdec":
            per = (self.d_model * self.n_heads * hd * 2
                   + 2 * self.d_model * self.n_kv_heads * hd
                   + (3 if self.mlp_kind == MLP_SWIGLU else 2) * self.d_model * self.d_ff
                   + 2 * self.d_model)
            total += self.enc_layers * per + self.enc_input_dim * self.d_model
        if self.family == "vlm":
            total += self.vision_dim * self.d_model  # projector
        return total

    def n_active_params(self) -> int:
        """Active (per-token) params — differs from n_params only for MoE."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        d = self.d_model
        per_layer_all = (m.n_routed + m.n_shared) * 3 * d * m.d_ff_expert
        per_layer_act = (m.top_k + m.n_shared) * 3 * d * m.d_ff_expert
        n_moe_layers = self.n_layers - m.first_moe_layer
        return self.n_params() - n_moe_layers * (per_layer_all - per_layer_act)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Train / serve / mesh configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup: int = 100
    schedule: str = "constant"     # constant | cosine
    grad_clip: float = 1.0


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    seed: int = 0
    optim: OptimConfig = field(default_factory=OptimConfig)
    remat: bool = True
    fsdp: bool = True              # shard params over the data axis too
    lora_rank: int = 0             # 0 = full fine-tune
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: str = ""


@dataclass(frozen=True)
class SageConfig:
    """Paper hyper-parameters (Alg. 1/2, Eq. 3)."""
    total_steps: int = 30          # DDIM steps T
    share_ratio: float = 0.3       # beta = (T - T*) / T
    guidance_scale: float = 7.5
    tau_min: float = 0.6
    tau_max: float = 0.9
    group_min: int = 2
    group_max: int = 5
    lambda1: float = 1.0
    lambda2: float = 0.5
    soft_target_stopgrad: bool = True
    adaptive_branch: bool = False  # T* from min pairwise similarity
    shared_uncond_cfg: bool = False  # beyond-paper: share CFG uncond pass
    clip_x0: float = 3.0           # x0-thresholding in the sampler
    sampler: str = "ddim"          # ddim | dpmpp (DPM-Solver++ 2M)
    # per-step update implementation (kernels.dispatch): "reference" is the
    # jnp cfg_combine + samplers.<solver>_step pair; "fused" routes BOTH
    # solvers through single-pass Pallas kernels — CFG+DDIM (3 reads /
    # 1 write) and CFG+DPM-Solver++(2M) (4 reads / 2 writes; the kernel
    # also emits the combined eps for the 2M history carry).
    step_impl: str = "reference"
    kernel_interpret: str = "auto"  # see ModelConfig.kernel_interpret

    @property
    def branch_point(self) -> int:
        return int(round(self.total_steps * (1.0 - self.share_ratio)))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]()


def list_archs() -> Sequence[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
