"""Partitioning rules: param/optimizer/KV-cache PartitionSpecs.

Scheme (DESIGN.md §5):
* tensor parallelism on the ``model`` axis — attention head / FFN-hidden /
  expert / vocab dims;
* optional FSDP: additionally shard a big *unsharded* dim over ``data``
  (training configs; params are all-gathered by GSPMD per layer);
* the ``pod`` axis is pure data parallelism (params replicated across pods);
* decode caches: batch over data; head-dim (or MLA latent dim) over model —
  heads themselves rarely divide a 16-wide axis (GQA kv ∈ {1, 8, 16, 40}).

Rules are name+shape driven over the *last two* dims; leading stack dims
(scan blocks, MoE expert dim) are handled positionally.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _name_of(part) -> str:
    return str(getattr(part, "key", getattr(part, "idx", part)))


# weights whose OUTPUT (last dim) carries the parallel dimension
_COL = ("wq", "wk", "wv", "wi", "wg", "wdkv", "wukv", "z_proj", "x_proj",
        "bc_proj", "dt_proj", "wx", "wa", "patch_in", "cond_proj", "adaln",
        "t_w1", "t_w2", "enc_in", "proj", "head", "final_adaln")
# weights whose INPUT (second-to-last dim) carries it (row-parallel)
_ROW = ("wo", "out", "out_proj")
_REPL = ("router", "conv_w", "conv_b", "A_log", "D", "dt_bias", "lam",
         "pos", "ln", "norm", "b", "ba", "bi", "bq", "bk", "bv")


def spec_for(cfg: ModelConfig, path: Tuple, shape: Tuple[int, ...],
             mesh: Mesh, fsdp: bool = False) -> P:
    names = [_name_of(p) for p in path]
    leaf = names[-1] if names else ""
    m = _axis_size(mesh, "model")
    d = _axis_size(mesh, "data")
    nd = len(shape)

    if nd == 0:
        return P()
    if nd == 1 or leaf.startswith("b") and nd == 1:
        return P(*([None] * nd))

    is_expert = any("moe" == n for n in names) and leaf in ("wi", "wg", "wo")
    base = 3 if is_expert else 2
    lead = [None] * (nd - base)

    def fits(dim: int, size: int) -> bool:
        return size > 1 and dim % size == 0

    if is_expert:
        # (E, d_model, ff) / (E, ff, d_model): experts over model
        e_ax = "model" if fits(shape[-3], m) else None
        spec = lead + [e_ax, None, None]
        if fsdp and fits(shape[-2], d):
            spec[-2] = "data"
        return P(*spec)

    if leaf == "embed":
        spec = lead + ["model" if fits(shape[-2], m) else None, None]
        if fsdp and fits(shape[-1], d):
            spec[-1] = "data"
        return P(*spec)

    if leaf in _ROW:
        spec = lead + ["model" if fits(shape[-2], m) else None, None]
        if fsdp and fits(shape[-1], d):
            spec[-1] = "data"
        return P(*spec)

    if leaf in _COL or leaf.startswith("w"):
        spec = lead + [None, "model" if fits(shape[-1], m) else None]
        if fsdp and fits(shape[-2], d):
            spec[-2] = "data"
        return P(*spec)

    return P(*([None] * nd))


def param_specs(cfg: ModelConfig, params_shapes, mesh: Mesh,
                fsdp: bool = False):
    """Pytree of PartitionSpec matching a params (shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(cfg, path, leaf.shape, mesh, fsdp),
        params_shapes)


def opt_specs(pspecs, opt_state_shapes):
    """Optimizer state mirrors param sharding; scalars replicated."""

    def fix(path, leaf):
        # walk down pspecs along the path *after* the top-level state key
        node: Any = None
        for part in path:
            name = _name_of(part)
            if node is None:
                node = pspecs if name in ("mu", "nu", "s") else "scalar"
                continue
            if node == "scalar":
                break
            if isinstance(node, dict) and name in node:
                node = node[name]
            elif isinstance(node, (list, tuple)):
                node = node[int(name)]
            else:
                break
        if isinstance(node, P):
            if len(node) == len(leaf.shape):
                return node
            # factored adafactor stats: drop trailing axes of the spec
            return P(*list(node)[:len(leaf.shape)])
        return P()

    return jax.tree_util.tree_map_with_path(fix, opt_state_shapes)


def batch_axes(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    """Largest prefix of (pod, data) whose product divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    chosen = []
    prod = 1
    for a in axes:
        if batch % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    return tuple(chosen) or None


def cache_specs(cfg: ModelConfig, cache_shapes, mesh: Mesh, batch: int,
                seq_shard: bool = False):
    """KV/state cache sharding for decode.

    seq_shard=True shards the cache *sequence* dim over `model` instead of
    heads/head-dim — softmax stats then reduce over the sharded key axis
    with small (B,H,1) collectives instead of all-reducing full score rows
    (§Perf collective-term variant)."""
    ba = batch_axes(mesh, batch)
    m = _axis_size(mesh, "model")

    def fix(path, leaf):
        names = [_name_of(p) for p in path]
        leafname = names[-1]
        nd = len(leaf.shape)
        # strip the scan-stack dim if present (blocks caches)
        has_stack = "blocks" in names and nd >= 3
        lead = [None] if has_stack else []
        core = list(leaf.shape[1:]) if has_stack else list(leaf.shape)

        def done(spec):
            return P(*(lead + spec))

        if leafname in ("k", "v"):          # (B, L, Hkv, hd)
            hkv, hd = core[2], core[3]
            if seq_shard and core[1] % m == 0:
                return done([ba, "model", None, None])
            if hkv % m == 0:
                return done([ba, None, "model", None])
            if hd % m == 0:
                return done([ba, None, None, "model"])
            return done([ba, None, None, None])
        if leafname == "ckv":               # (B, L, r)
            if seq_shard and core[1] % m == 0:
                return done([ba, "model", None])
            return done([ba, None, "model" if core[2] % m == 0 else None])
        if leafname == "kr":                # (B, L, rope_hd)
            return done([ba, None, None])
        if leafname == "conv":              # (B, K-1, C)
            return done([ba, None, "model" if core[2] % m == 0 else None])
        if leafname == "state":             # ssm (B,H,P,N) / rglru (B,W)
            if len(core) == 4:
                ax = "model" if core[1] % m == 0 else None
                return done([ba, ax, None, None])
            return done([ba, "model" if core[1] % m == 0 else None])
        return done([ba] + [None] * (len(core) - 1))

    return jax.tree_util.tree_map_with_path(fix, cache_shapes)


def shard_tree(tree, specs, mesh: Mesh):
    """Attach NamedShardings: returns ShapeDtypeStructs for AOT lowering."""
    return jax.tree.map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)),
        tree, specs)
