from repro.sharding.partition import (batch_axes, cache_specs, opt_specs,
                                      param_specs, shard_tree)
