"""Equation 3 — the SAGE training objective.

L_SAGE = E[ lambda1 * w_ts ||eps_th(a_ts z̄ + s_ts e, c̄) - e||^2          (i)
           + lambda2 * ||eps_th(a_ts z̄ + s_ts e, c̄) - soft_target||^2    (ii)
           + (1/N) sum_n w_tb ||eps_th(a_tb z^n + s_tb e, c^n) - e||^2 ]  (iii)

soft_target = (1/N) sum_n eps_th(a_ts z^n + s_ts e, c^n)   (stop-grad by
default — distillation semantics; configurable).

(i)+(ii) supervise the *shared phase* (t_s ~ U{T*..T}); (iii) is the
*branch phase* loss (t_b ~ U{1..T*}).  One shared noise e per group
(Alg. 2 line 7).  All member evals are batched into a single eps_fn call
so the loss costs (2N + 1) model evals per group, fused.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import SageConfig
from repro.core.schedule import Schedule
from repro.core.shared_sampling import group_mean

EpsFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def sample_group_timesteps(key, sage: SageConfig, sched: Schedule, n: int
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """t_s ~ U{T*..T}, t_b ~ U{1..T*} on the continuous training grid
    (branch point mapped from the sampler grid to [0, sched.T])."""
    ks, kb = jax.random.split(key)
    ts_lo = int(sched.T * (1.0 - sage.share_ratio))
    t_s = jax.random.randint(ks, (n,), ts_lo, sched.T + 1)
    t_b = jax.random.randint(kb, (n,), 1, max(ts_lo, 2))
    return t_s, t_b


def sage_loss(eps_fn: EpsFn, sched: Schedule, sage: SageConfig, key,
              z: jnp.ndarray, cond: jnp.ndarray, mask: jnp.ndarray
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """z (K,N,H,W,C) clean member latents; cond (K,N,Lc,dc); mask (K,N)."""
    K, N, H, W, C = z.shape
    kt, ke = jax.random.split(key)
    t_s, t_b = sample_group_timesteps(kt, sage, sched, K)
    eps = jax.random.normal(ke, (K, H, W, C))              # shared per group

    zbar = group_mean(z, mask)                             # (K,H,W,C)
    cbar = group_mean(cond, mask)                          # (K,Lc,dc)

    def noise(z_, t_):
        a = sched.alpha(t_).reshape(-1, 1, 1, 1)
        s = sched.sigma(t_).reshape(-1, 1, 1, 1)
        return a * z_ + s * jnp.repeat(eps, z_.shape[0] // K, axis=0)

    # one fused eps_fn call: [shared(K) | members@ts(K*N) | members@tb(K*N)]
    zm = z.reshape(K * N, H, W, C)
    cm = cond.reshape(K * N, *cond.shape[2:])
    t_s_m = jnp.repeat(t_s, N)
    t_b_m = jnp.repeat(t_b, N)
    z_in = jnp.concatenate([noise(zbar, t_s), noise(zm, t_s_m),
                            noise(zm, t_b_m)], 0)
    t_in = jnp.concatenate([t_s, t_s_m, t_b_m], 0)
    c_in = jnp.concatenate([cbar, cm, cm], 0)
    pred = eps_fn(z_in, t_in, c_in)

    pred_shared = pred[:K]
    pred_m_ts = pred[K:K + K * N].reshape(K, N, H, W, C)
    pred_m_tb = pred[K + K * N:].reshape(K, N, H, W, C)

    def mse(a, b, axis):
        return jnp.mean((a - b) ** 2, axis=axis)

    w_ts = sched.snr_weight(t_s)
    w_tb = sched.snr_weight(t_b)

    # (i) shared-phase denoising faithfulness
    l1 = jnp.mean(w_ts * mse(pred_shared, eps, axis=(1, 2, 3)))

    # (ii) soft-target alignment
    soft = group_mean(pred_m_ts, mask)
    if sage.soft_target_stopgrad:
        soft = jax.lax.stop_gradient(soft)
    l2 = jnp.mean(mse(pred_shared, soft, axis=(1, 2, 3)))

    # (iii) branch-phase per-member fidelity
    per_m = mse(pred_m_tb, eps[:, None], axis=(2, 3, 4))    # (K,N)
    l3 = jnp.mean(w_tb * jnp.sum(per_m * mask, 1)
                  / jnp.maximum(jnp.sum(mask, 1), 1e-6))

    loss = sage.lambda1 * l1 + sage.lambda2 * l2 + l3
    return loss, {"shared": l1, "soft": l2, "branch": l3}


def ldm_loss(eps_fn: EpsFn, sched: Schedule, key, z: jnp.ndarray,
             cond: jnp.ndarray) -> jnp.ndarray:
    """Standard LDM objective (paper Eq. 2) — the Standard-FT baseline."""
    B = z.shape[0]
    kt, ke = jax.random.split(key)
    t = jax.random.randint(kt, (B,), 1, sched.T + 1)
    eps = jax.random.normal(ke, z.shape)
    a = sched.alpha(t).reshape(-1, 1, 1, 1)
    s = sched.sigma(t).reshape(-1, 1, 1, 1)
    pred = eps_fn(a * z + s * eps, t, cond)
    w = sched.snr_weight(t)
    return jnp.mean(w * jnp.mean((pred - eps) ** 2, axis=(1, 2, 3)))
