"""Semantic grouping of prompts (paper §2.2 + §3.1 dataset construction).

Prompts are nodes; edges connect pairs whose embedding cosine similarity
falls in (tau_min, tau_max].  Sampling-time grouping uses a greedy clique
cover (every pair inside a group must be an edge — exactly the paper's
constraint; exact max-clique enumeration is NP-hard, greedy is the
deployable choice and is what we benchmark).  Group sizes are clamped to
[group_min, group_max]; leftovers become singleton groups (independent
sampling).

Host-side numpy — grouping is control-flow-heavy graph work that belongs on
the scheduler CPU, not the TPU (DESIGN.md §2).  The device-side math
(masked group means) lives in kernels/group_mean.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


# Default tau_max: edges live in the OPEN-BELOW interval (tau_min, tau_max]
# (see ``edge_mask``); 1.01 > 1.0 keeps exact-duplicate prompts (cosine
# == 1.0 up to float error) groupable under the default.
DEFAULT_TAU_MAX = 1.01


def similarity_matrix(embeds: np.ndarray) -> np.ndarray:
    """embeds (M, d), L2-normalised -> (M, M) cosine similarity."""
    e = np.asarray(embeds, np.float32)
    e = e / np.maximum(np.linalg.norm(e, axis=-1, keepdims=True), 1e-8)
    return e @ e.T


def edge_mask(sim: np.ndarray, tau_min: float,
              tau_max: float = DEFAULT_TAU_MAX) -> np.ndarray:
    """THE tau interval convention, in one place: a pair is an edge iff its
    cosine similarity falls in the half-open interval ``(tau_min, tau_max]``
    — strictly above tau_min (tau_min itself is *not* similar enough),
    up to and including tau_max.  Every grouping consumer
    (``greedy_clique_groups``, ``incremental_assign``, the serving engine
    and ``serving.shared_prefill``) goes through this helper rather than
    re-encoding the comparison.
    """
    if not tau_min < tau_max:
        raise ValueError(
            f"tau interval empty: need tau_min < tau_max, got "
            f"({tau_min}, {tau_max}]")
    return (sim > tau_min) & (sim <= tau_max)


def greedy_clique_groups(sim: np.ndarray, tau_min: float,
                         tau_max: float = DEFAULT_TAU_MAX, group_max: int = 5
                         ) -> List[List[int]]:
    """Greedy clique cover of the threshold graph.

    Nodes are visited in decreasing degree order; each seed greedily absorbs
    the most-similar compatible candidates (compatible = edge to EVERY
    current member, the paper's pairwise constraint).  Edges follow the
    ``edge_mask`` (tau_min, tau_max] convention.
    """
    if group_max < 1:
        raise ValueError(f"group_max must be >= 1, got {group_max}")
    M = sim.shape[0]
    adj = edge_mask(sim, tau_min, tau_max)
    np.fill_diagonal(adj, False)
    degree = adj.sum(1)
    unassigned = np.ones(M, bool)
    groups: List[List[int]] = []
    for seed in np.argsort(-degree):
        if not unassigned[seed]:
            continue
        members = [int(seed)]
        unassigned[seed] = False
        cand_mask = adj[seed] & unassigned
        # highest-similarity-first absorption
        for cand in np.argsort(-sim[seed]):
            if len(members) >= group_max:
                break
            if not cand_mask[cand]:
                continue
            if all(adj[cand, m] for m in members):
                members.append(int(cand))
                unassigned[cand] = False
        groups.append(members)
    return groups


def incremental_assign(new_embed: np.ndarray,
                       group_embeds: Sequence[np.ndarray], tau_min: float,
                       tau_max: float = DEFAULT_TAU_MAX,
                       group_max: int = 5) -> int:
    """Continuous-batching admission: attach ONE arriving request to an
    existing *open* group, or signal that it should seed a new group.

    ``group_embeds[i]`` is the (n_i, d) stack of member embeddings of open
    group i.  The request may join a group iff it has an edge — the
    ``edge_mask`` (tau_min, tau_max] convention — to EVERY current member
    (the same pairwise clique constraint ``greedy_clique_groups`` enforces,
    so incrementally-built groups satisfy the identical invariant) and the
    group is not full.  Among admissible groups the one with the highest
    minimum similarity (tightest resulting clique) wins.

    Returns the chosen group index, or -1 to seed a new group.
    """
    if group_max < 1:
        raise ValueError(f"group_max must be >= 1, got {group_max}")
    e = np.asarray(new_embed, np.float32).reshape(-1)
    e = e / max(float(np.linalg.norm(e)), 1e-8)
    best, best_score = -1, -np.inf
    for gi, members in enumerate(group_embeds):
        m = np.asarray(members, np.float32)
        if m.shape[0] >= group_max:
            continue
        m = m / np.maximum(np.linalg.norm(m, axis=-1, keepdims=True), 1e-8)
        sims = m @ e
        if not np.all(edge_mask(sims, tau_min, tau_max)):
            continue
        score = float(sims.min())
        if score > best_score:
            best, best_score = gi, score
    return best


def flatten_groups(groups: Sequence[Sequence[int]], group_size: int
                   ) -> List[List[int]]:
    """Split oversize groups into packed rows of at most ``group_size`` —
    the row order of :func:`pad_groups`.  Exposed so completion unpacking
    can map packed row k back to the right member indices (a clique larger
    than N occupies *multiple* rows; iterating the unsplit groups
    misaligns every row after the first split)."""
    flat: List[List[int]] = []
    for g in groups:
        for i in range(0, len(g), group_size):
            flat.append(list(g[i:i + group_size]))
    return flat


def pad_groups(groups: Sequence[Sequence[int]], group_size: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Static-shape packing: (K, N) member indices + (K, N) validity mask.

    Groups larger than N are split (see :func:`flatten_groups`, which
    defines the packed row order); padding repeats the first member (its
    compute is masked out of all reductions).
    """
    flat = flatten_groups(groups, group_size)
    K = len(flat)
    idx = np.zeros((K, group_size), np.int32)
    mask = np.zeros((K, group_size), np.float32)
    for k, g in enumerate(flat):
        idx[k, :len(g)] = g
        idx[k, len(g):] = g[0]
        mask[k, :len(g)] = 1.0
    return idx, mask


def cost_saving(groups: Sequence[Sequence[int]], total_steps: int,
                branch_point: int, cfg_evals: int = 2,
                shared_uncond: bool = False) -> dict:
    """Paper's cost-saving ratio: reduction in total sampler NFE relative to
    independent sampling of the same M prompts.

    independent:   M * T * cfg_evals
    shared (ours): K * (T - T*) * cfg_evals    (shared phase)
                 + sum_k N_k * T* * e_b        (branch phase)
    where e_b = cfg_evals, or 1 + 1/N_k with the beyond-paper shared-uncond
    CFG (the unconditional eval is group-level, amortised over members).
    """
    M = sum(len(g) for g in groups)
    K = len(groups)
    T, Ts = total_steps, branch_point
    indep = M * T * cfg_evals
    shared_phase = K * (T - Ts) * cfg_evals
    if shared_uncond:
        branch_phase = sum((len(g) + 1) * Ts for g in groups)
    else:
        branch_phase = sum(len(g) * Ts * cfg_evals for g in groups)
    ours = shared_phase + branch_phase
    return {"M": M, "K": K, "nfe_independent": indep, "nfe_shared": ours,
            "saving": 1.0 - ours / indep}


def adaptive_branch_point(sim_min: float, total_steps: int,
                          beta_max: float = 0.5) -> int:
    """Beyond-fixed-T* option the paper mentions (§2.2): share more steps
    when the group is tighter.  Linear map sim in [0,1] -> beta in
    [0, beta_max]; returns T* (steps remaining for the branch phase)."""
    beta = float(np.clip(sim_min, 0.0, 1.0)) * beta_max
    return int(round(total_steps * (1.0 - beta)))
