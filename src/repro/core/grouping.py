"""Semantic grouping of prompts (paper §2.2 + §3.1 dataset construction).

Prompts are nodes; edges connect pairs whose embedding cosine similarity
falls in (tau_min, tau_max].  Sampling-time grouping uses a greedy clique
cover (every pair inside a group must be an edge — exactly the paper's
constraint; exact max-clique enumeration is NP-hard, greedy is the
deployable choice and is what we benchmark).  Group sizes are clamped to
[group_min, group_max]; leftovers become singleton groups (independent
sampling).

Host-side numpy — grouping is control-flow-heavy graph work that belongs on
the scheduler CPU, not the TPU (DESIGN.md §2).  The device-side math
(masked group means) lives in kernels/group_mean.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def similarity_matrix(embeds: np.ndarray) -> np.ndarray:
    """embeds (M, d), L2-normalised -> (M, M) cosine similarity."""
    e = np.asarray(embeds, np.float32)
    e = e / np.maximum(np.linalg.norm(e, axis=-1, keepdims=True), 1e-8)
    return e @ e.T


def greedy_clique_groups(sim: np.ndarray, tau_min: float,
                         tau_max: float = 1.01, group_max: int = 5
                         ) -> List[List[int]]:
    """Greedy clique cover of the threshold graph.

    Nodes are visited in decreasing degree order; each seed greedily absorbs
    the most-similar compatible candidates (compatible = edge to EVERY
    current member, the paper's pairwise constraint).
    """
    M = sim.shape[0]
    adj = (sim > tau_min) & (sim <= tau_max)
    np.fill_diagonal(adj, False)
    degree = adj.sum(1)
    unassigned = np.ones(M, bool)
    groups: List[List[int]] = []
    for seed in np.argsort(-degree):
        if not unassigned[seed]:
            continue
        members = [int(seed)]
        unassigned[seed] = False
        cand_mask = adj[seed] & unassigned
        # highest-similarity-first absorption
        for cand in np.argsort(-sim[seed]):
            if len(members) >= group_max:
                break
            if not cand_mask[cand]:
                continue
            if all(adj[cand, m] for m in members):
                members.append(int(cand))
                unassigned[cand] = False
        groups.append(members)
    return groups


def pad_groups(groups: Sequence[Sequence[int]], group_size: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Static-shape packing: (K, N) member indices + (K, N) validity mask.

    Groups larger than N are split; padding repeats the first member (its
    compute is masked out of all reductions).
    """
    flat: List[List[int]] = []
    for g in groups:
        for i in range(0, len(g), group_size):
            flat.append(list(g[i:i + group_size]))
    K = len(flat)
    idx = np.zeros((K, group_size), np.int32)
    mask = np.zeros((K, group_size), np.float32)
    for k, g in enumerate(flat):
        idx[k, :len(g)] = g
        idx[k, len(g):] = g[0]
        mask[k, :len(g)] = 1.0
    return idx, mask


def cost_saving(groups: Sequence[Sequence[int]], total_steps: int,
                branch_point: int, cfg_evals: int = 2,
                shared_uncond: bool = False) -> dict:
    """Paper's cost-saving ratio: reduction in total sampler NFE relative to
    independent sampling of the same M prompts.

    independent:   M * T * cfg_evals
    shared (ours): K * (T - T*) * cfg_evals    (shared phase)
                 + sum_k N_k * T* * e_b        (branch phase)
    where e_b = cfg_evals, or 1 + 1/N_k with the beyond-paper shared-uncond
    CFG (the unconditional eval is group-level, amortised over members).
    """
    M = sum(len(g) for g in groups)
    K = len(groups)
    T, Ts = total_steps, branch_point
    indep = M * T * cfg_evals
    shared_phase = K * (T - Ts) * cfg_evals
    if shared_uncond:
        branch_phase = sum((len(g) + 1) * Ts for g in groups)
    else:
        branch_phase = sum(len(g) * Ts * cfg_evals for g in groups)
    ours = shared_phase + branch_phase
    return {"M": M, "K": K, "nfe_independent": indep, "nfe_shared": ours,
            "saving": 1.0 - ours / indep}


def adaptive_branch_point(sim_min: float, total_steps: int,
                          beta_max: float = 0.5) -> int:
    """Beyond-fixed-T* option the paper mentions (§2.2): share more steps
    when the group is tighter.  Linear map sim in [0,1] -> beta in
    [0, beta_max]; returns T* (steps remaining for the branch phase)."""
    beta = float(np.clip(sim_min, 0.0, 1.0)) * beta_max
    return int(round(total_steps * (1.0 - beta)))
