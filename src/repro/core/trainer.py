"""Algorithm 2 — Shared Diffusion Training, plus the Standard-FT baseline.

Functional train-step factories; state = {"params", "lora", "opt", "step"}.
When ``lora_rank > 0`` only the LoRA pytree is optimised (paper §3.1);
otherwise full fine-tune.  10% condition dropout trains the null branch for
CFG (standard LDM practice; the null condition is the zero tensor).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, OptimConfig, SageConfig
from repro.core import lora as lora_lib
from repro.core import sage_loss as losses
from repro.core.schedule import Schedule
from repro.models import dit
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    make_optimizer)

Params = Dict[str, Any]

COND_DROP = 0.1


def init_state(model_cfg: ModelConfig, opt_cfg: OptimConfig, key,
               lora_rank: int = 0, base_params: Optional[Params] = None
               ) -> Dict[str, Any]:
    kp, kl = jax.random.split(key)
    params = base_params if base_params is not None else dit.init_params(
        model_cfg, kp)
    opt = make_optimizer(opt_cfg)
    if lora_rank:
        lo = lora_lib.init_lora(params, lora_rank, kl)
        opt_state = opt.init(lo)
    else:
        lo = None
        opt_state = opt.init(params)
    return {"params": params, "lora": lo, "opt": opt_state,
            "step": jnp.zeros((), jnp.int32)}


def _eps_fn(model_cfg: ModelConfig, params: Params, lo: Optional[Params],
            remat: bool = False):
    eff = lora_lib.merge(params, lo) if lo is not None else params

    def eps_fn(z, t, c):
        return dit.forward(eff, model_cfg, z, t, c, remat=remat)

    return eps_fn


def _drop_cond(key, cond: jnp.ndarray, batch_dims: int) -> jnp.ndarray:
    shape = cond.shape[:batch_dims]
    keep = (jax.random.uniform(key, shape) > COND_DROP)
    return cond * keep.reshape(shape + (1,) * (cond.ndim - batch_dims)
                               ).astype(cond.dtype)


def make_sage_train_step(model_cfg: ModelConfig, sage: SageConfig,
                         sched: Schedule, opt_cfg: OptimConfig,
                         lora_rank: int = 0, remat: bool = False):
    """batch = {"z": (K,N,H,W,C), "cond": (K,N,Lc,dc), "mask": (K,N)}."""
    opt = make_optimizer(opt_cfg)

    def loss_fn(trainable, frozen, batch, key):
        params, lo = ((frozen, trainable) if lora_rank
                      else (trainable, None))
        kd, kl = jax.random.split(key)
        cond = _drop_cond(kd, batch["cond"], 2)
        eps_fn = _eps_fn(model_cfg, params, lo, remat)
        return losses.sage_loss(eps_fn, sched, sage, kl, batch["z"], cond,
                                batch["mask"])

    @jax.jit
    def step(state, batch, key):
        trainable = state["lora"] if lora_rank else state["params"]
        frozen = state["params"] if lora_rank else None
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable, frozen, batch, key)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        updates, opt_state = opt.update(grads, state["opt"], trainable,
                                        opt_cfg.lr)
        new_trainable = apply_updates(trainable, updates)
        new_state = dict(state)
        new_state["opt"] = opt_state
        new_state["step"] = state["step"] + 1
        if lora_rank:
            new_state["lora"] = new_trainable
        else:
            new_state["params"] = new_trainable
        metrics = {"loss": loss, "gnorm": gnorm, **parts}
        return new_state, metrics

    return step


def make_standard_train_step(model_cfg: ModelConfig, sched: Schedule,
                             opt_cfg: OptimConfig, lora_rank: int = 0,
                             remat: bool = False):
    """Standard-FT baseline: plain LDM loss on individual (z, c) pairs.
    batch = {"z": (B,H,W,C), "cond": (B,Lc,dc)}."""
    opt = make_optimizer(opt_cfg)

    def loss_fn(trainable, frozen, batch, key):
        params, lo = ((frozen, trainable) if lora_rank
                      else (trainable, None))
        kd, kl = jax.random.split(key)
        cond = _drop_cond(kd, batch["cond"], 1)
        eps_fn = _eps_fn(model_cfg, params, lo, remat)
        return losses.ldm_loss(eps_fn, sched, kl, batch["z"], cond)

    @jax.jit
    def step(state, batch, key):
        trainable = state["lora"] if lora_rank else state["params"]
        frozen = state["params"] if lora_rank else None
        loss, grads = jax.value_and_grad(loss_fn)(trainable, frozen, batch,
                                                  key)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        updates, opt_state = opt.update(grads, state["opt"], trainable,
                                        opt_cfg.lr)
        new_trainable = apply_updates(trainable, updates)
        new_state = dict(state)
        new_state["opt"] = opt_state
        new_state["step"] = state["step"] + 1
        if lora_rank:
            new_state["lora"] = new_trainable
        else:
            new_state["params"] = new_trainable
        return new_state, {"loss": loss, "gnorm": gnorm}

    return step
