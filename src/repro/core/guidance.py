"""Classifier-free guidance utilities."""
from __future__ import annotations

import jax.numpy as jnp


def cfg_combine(eps_uncond: jnp.ndarray, eps_cond: jnp.ndarray,
                scale: float) -> jnp.ndarray:
    """eps = eps_u + w * (eps_c - eps_u).  (paper: w = 7.5, DDIM.)"""
    return eps_uncond + scale * (eps_cond - eps_uncond)
