"""SAGE core — the paper's contribution (Alg. 1 shared sampling, Alg. 2
training, Eq. 3 loss), plus grouping, guidance, LoRA, metrics."""
