"""Noise schedules for the latent diffusion formulation (paper Eq. 1).

Variance-preserving: q_t(z_t|z_0) = N(alpha_t z_0, sigma_t^2 I) with
alpha_t^2 + sigma_t^2 = 1.  Discrete T=1000 training grid; DDIM uses an
evenly strided subset (paper: 30 steps).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Schedule:
    alphas: jnp.ndarray        # (T+1,) alpha_t, t=0..T  (alpha_0 = 1)
    sigmas: jnp.ndarray        # (T+1,)
    T: int

    def alpha(self, t):
        return jnp.take(self.alphas, t)

    def sigma(self, t):
        return jnp.take(self.sigmas, t)

    def snr_weight(self, t):
        """w_t — min-SNR-style clamp of SNR (stable epsilon-loss weight)."""
        a, s = self.alpha(t), self.sigma(t)
        snr = (a / jnp.maximum(s, 1e-5)) ** 2
        return jnp.minimum(snr, 5.0) / 5.0


def make_schedule(T: int = 1000, kind: str = "cosine") -> Schedule:
    t = np.linspace(0.0, 1.0, T + 1)
    if kind == "cosine":
        f = np.cos((t + 0.008) / 1.008 * np.pi / 2) ** 2
        abar = np.clip(f / f[0], 1e-8, 1.0)
    elif kind == "linear":
        betas = np.linspace(1e-4, 2e-2, T + 1)
        betas[0] = 0.0
        abar = np.cumprod(1.0 - betas)
    else:
        raise ValueError(kind)
    alphas = np.sqrt(abar)
    sigmas = np.sqrt(1.0 - abar)
    return Schedule(jnp.asarray(alphas, jnp.float32),
                    jnp.asarray(sigmas, jnp.float32), T)


def ddim_timesteps(T: int, n_steps: int) -> np.ndarray:
    """Descending sample-time grid t_n, n = n_steps..1, plus terminal 0.

    Returns int array (n_steps+1,) from high noise to t=0."""
    ts = np.linspace(T, 0, n_steps + 1).round().astype(np.int64)
    return ts
