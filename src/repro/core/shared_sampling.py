"""Algorithm 1 — Shared Diffusion Sampling (the paper's inference scheme).

Static-shape, device-side implementation:

* groups are packed to (K, N) member indices + mask by ``core.grouping``;
* shared phase: K latents, conditioned on the masked mean text features c̄,
  for t = T .. T* (``n_shared`` sampler steps);
* branch phase: latents broadcast K -> (K, N), each member continues with
  its own cⁿ for t = T* .. 0;
* CFG with a null-condition pass; the beyond-paper ``shared_uncond`` option
  computes the unconditional branch once per *group* during branching (it is
  prompt-independent along a shared trajectory) — NFE drops from 2N to N+1
  per step with no change in output for identical uncond inputs.

Timestep loops are ``lax.scan`` over the DDIM grid (static trip counts;
branch point is a static Python int — adaptive T* selects among a small set
of compiled variants, see ``serve.py``).

Resumable segments (serving scheduler support): the two phases are exposed
as ``shared_phase(carry, n_steps)`` / ``branch_phase(carry, n_steps)`` over
an explicit :class:`SampleCarry` ``(z, eps_prev, step_idx)``, so a
continuous-batching scheduler (``repro.serving.scheduler``) can advance an
in-flight group a *slice* of S steps per engine tick and a trunk cache can
checkpoint/restore the shared phase.  ``step_idx`` is a traced scalar —
one jit compilation covers every slice position of the same length — and
``shared_sample`` is a thin wrapper (segment sizes = whole phases), so the
one-shot path and the sliced path run the identical per-step graph.

Stacked carries (packed serving support): ``step_idx`` may instead be a
per-row (B,) vector — and ``branch_phase``'s ``fork_idx`` a matching
per-row vector — so several groups sitting at *different* positions on
the DDIM grid can ride ONE phase call as one super-batch
(``repro.serving.packing`` builds/unpacks these).  Every schedule gather
then returns per-row values which broadcast along the batch axis; the
per-element arithmetic is unchanged, so packed rows reproduce the
per-group results exactly.

Heterogeneous stacks (hetero packed serving support): both phases accept
an explicit ``grid`` — 1-D to override the default
``ddim_timesteps(sched.T, sage.total_steps)`` (quality tiers: groups run
at their OWN total_steps), or 2-D (B, L) so every packed row gathers from
its own group's grid (rows with *different* step budgets in one launch;
``repro.serving.packing.pack_grid`` builds these).  ``row_samplers`` — a
static per-row tuple of sampler names — additionally lets rows of
different solvers share the stack: row-independent math means each
sub-batch reproduces its per-group result bitwise (reference path:
compute both updates, select per row; fused path: dispatch each solver's
kernel over its row subset and scatter — the per-row scalar-block
kernels already pin sub-batch == solo bitwise).

Kernel routing: ``sage.step_impl == "fused"`` sends the per-step CFG+solver
update — DDIM *and* DPM-Solver++(2M) — plus the shared-uncond group mean
through the Pallas kernels via ``repro.kernels.dispatch``: one HBM pass
instead of 3+ elementwise passes per step (the dpmpp kernel also returns
the combined eps so the 2M history carry costs no extra pass); the
denoiser's attention backend is chosen separately by
``ModelConfig.attn_impl``.
"""
from __future__ import annotations

from dataclasses import replace as _dc_replace
from typing import (Callable, Dict, NamedTuple, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, SageConfig
from repro.core import samplers
from repro.core.guidance import cfg_combine
from repro.core.schedule import Schedule, ddim_timesteps
from repro.kernels import dispatch
from repro.kernels._tiles import bcast_rows

# eps_fn(z, t, cond) -> eps ; z (B,H,W,C), t (B,), cond (B,Lc,dc)
EpsFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def group_mean(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Masked mean over the member axis.  x (K,N,...), mask (K,N)."""
    from repro.kernels.group_mean.ref import masked_group_mean_ref
    return masked_group_mean_ref(x, mask)


def _fused_step(sage: SageConfig) -> bool:
    """Single gate for the fused Pallas step path — both solvers the paper
    evaluates (DDIM and DPM-Solver++(2M)) have fused kernels; the
    shared-uncond group mean rides the same gate."""
    return sage.step_impl == "fused"


def _grid_gather(grid: jnp.ndarray, i) -> jnp.ndarray:
    """Gather timestep values for (possibly per-row) grid positions.

    1-D grid: every row shares one DDIM grid — plain ``grid[i]`` (``i``
    scalar or per-row), the homogeneous fast path, graph-identical to the
    pre-hetero code.  2-D grid (B, L): each row carries its OWN grid
    (groups with different ``total_steps`` stacked into one launch) — row
    j reads ``grid[j, i_j]``.  Rows shorter than L are zero-padded by
    ``packing.pack_grid``; a row's scan never indexes past its own
    ``total_steps``, so pads are never read.
    """
    if grid.ndim == 1:
        return grid[i]
    i = jnp.broadcast_to(jnp.asarray(i, jnp.int32), (grid.shape[0],))
    return jnp.take_along_axis(grid, i[:, None], axis=1)[:, 0]


def _norm_row_samplers(sage: SageConfig,
                       row_samplers: Optional[Sequence[str]]
                       ) -> Tuple[SageConfig, Optional[Tuple[str, ...]]]:
    """Collapse a uniform per-row sampler assignment back onto the scalar
    ``sage.sampler`` path (bitwise-identical and cheaper); keep the tuple
    only when rows genuinely mix solvers."""
    if row_samplers is None:
        return sage, None
    row_samplers = tuple(row_samplers)
    if len(set(row_samplers)) == 1:
        return _dc_replace(sage, sampler=row_samplers[0]), None
    return sage, row_samplers


def _eps_pair(eps_fn: EpsFn, z, t, cond, null_cond):
    """One batched denoiser call for the CFG pair -> (eps_u, eps_c)."""
    B = z.shape[0]
    zz = jnp.concatenate([z, z], 0)
    tt = jnp.concatenate([t, t], 0)
    cc = jnp.concatenate([jnp.broadcast_to(null_cond, cond.shape), cond], 0)
    eps = eps_fn(zz, tt, cc)
    return eps[:B], eps[B:]


def _sampler_update(sched: Schedule, sage: SageConfig, z, t, t_next, eps,
                    eps_prev, t_prev, is_first):
    """Dispatch DDIM / DPM-Solver++(2M); history handled via jnp.where so
    the whole thing stays scannable (first step falls back to 1st order
    by aliasing eps_prev = eps)."""
    if sage.sampler == "dpmpp":
        ep = jnp.where(bcast_rows(is_first, z.ndim), eps, eps_prev)
        return samplers.dpmpp_2m_step(sched, z, t, t_next, eps, ep, t_prev,
                                      clip_x0=sage.clip_x0)
    return samplers.ddim_step(sched, z, t, t_next, eps,
                              clip_x0=sage.clip_x0)


def _mixed_step_reference(sched: Schedule, sage: SageConfig, z, t, t_next,
                          eps_u, eps_c, eps_prev, t_prev, is_first,
                          row_samplers: Tuple[str, ...]):
    """Mixed-sampler reference update: gather each solver's (static) row
    subset, apply that solver's solo update, scatter back.  Computing
    BOTH solvers on the full stack and where-selecting per row would be
    value-equal but not bitwise-safe — XLA fuses the combined graph
    differently from the solo graphs (CSE/fma reassociation at the last
    bit).  The subset form keeps each row's elementwise expression tree
    literally the solo one; both solo reference paths return the combined
    eps as the history carry, so one full-stack eps serves every row."""
    eps = cfg_combine(eps_u, eps_c, sage.guidance_scale)
    B = z.shape[0]
    tb = jnp.broadcast_to(t, (B,))
    tnb = jnp.broadcast_to(t_next, (B,))
    tpb = jnp.broadcast_to(t_prev, (B,))
    fb = jnp.broadcast_to(is_first, (B,))
    z_next = jnp.zeros_like(z)
    for name in ("ddim", "dpmpp"):
        idx = tuple(j for j, s in enumerate(row_samplers) if s == name)
        if not idx:
            continue
        ix = jnp.asarray(idx)
        sub = _sampler_update(sched, _dc_replace(sage, sampler=name),
                              z[ix], tb[ix], tnb[ix], eps[ix],
                              eps_prev[ix], tpb[ix], fb[ix])
        z_next = z_next.at[ix].set(sub)
    return z_next, eps


def _mixed_step_fused(sched: Schedule, sage: SageConfig, z, t, t_next,
                      eps_u, eps_c, eps_prev, t_prev, is_first,
                      row_samplers: Tuple[str, ...]):
    """Mixed-sampler fused update: row-level dispatch fallback for kernels
    that can't mix solvers in one launch.  Each solver's kernel runs over
    its (static) row subset and the results scatter back — the per-row
    scalar-block machinery already pins sub-batch launches bitwise-equal
    to solo launches (``tests/test_packing.py`` rows-vs-single contracts),
    so the split is invisible.  History per row matches the solo fused
    paths exactly: DDIM rows carry ``eps_c``, 2M rows carry the kernel's
    combined eps."""
    B = z.shape[0]
    tb = jnp.broadcast_to(t, (B,))
    tnb = jnp.broadcast_to(t_next, (B,))
    tpb = jnp.broadcast_to(t_prev, (B,))
    fb = jnp.broadcast_to(is_first, (B,))
    z_next, eps_hist = jnp.zeros_like(z), jnp.zeros_like(z)
    idx_dd = tuple(j for j, s in enumerate(row_samplers) if s != "dpmpp")
    idx_dp = tuple(j for j, s in enumerate(row_samplers) if s == "dpmpp")
    if idx_dd:
        ix = jnp.asarray(idx_dd)
        a_t, s_t, a_n, s_n = samplers.ddim_scalars(sched, tb[ix], tnb[ix])
        zd = dispatch.cfg_ddim_step(
            z[ix], eps_u[ix], eps_c[ix], guidance=sage.guidance_scale,
            a_t=a_t, s_t=s_t, a_n=a_n, s_n=s_n, clip_x0=sage.clip_x0,
            impl="fused", interpret=sage.kernel_interpret)
        z_next = z_next.at[ix].set(zd)
        eps_hist = eps_hist.at[ix].set(eps_c[ix])
    if idx_dp:
        ix = jnp.asarray(idx_dp)
        a_t, s_t, a_n, s_n, lam, lam_p, lam_n = samplers.dpmpp_scalars(
            sched, tb[ix], tnb[ix], tpb[ix])
        zd, ed = dispatch.cfg_dpmpp_step(
            z[ix], eps_u[ix], eps_c[ix], eps_prev[ix],
            guidance=sage.guidance_scale, a_t=a_t, s_t=s_t, a_n=a_n,
            s_n=s_n, lam=lam, lam_p=lam_p, lam_n=lam_n, is_first=fb[ix],
            clip_x0=sage.clip_x0, impl="fused",
            interpret=sage.kernel_interpret)
        z_next = z_next.at[ix].set(zd)
        eps_hist = eps_hist.at[ix].set(ed)
    return z_next, eps_hist


def _step_update(sched: Schedule, sage: SageConfig, z, t, t_next,
                 eps_u, eps_c, eps_prev, t_prev, is_first,
                 row_samplers: Optional[Tuple[str, ...]] = None):
    """Apply one sampler update to the CFG pair; returns (z_next, eps).

    ``sage.step_impl == "fused"`` routes through the single-pass Pallas
    kernels: CFG+DDIM is 3 tile reads / 1 write, CFG+DPM-Solver++(2M) is
    4 reads / 2 writes (the kernel also emits the combined eps for the 2M
    history carry) — no intermediate combined-eps / x0 HBM round trips
    either way.  The returned eps feeds dpmpp's history carry and is never
    read on the DDIM path.  A non-None ``row_samplers`` tuple routes to
    the mixed-sampler per-row dispatch instead (rows of different solvers
    in one stack)."""
    if row_samplers is not None:
        mixed = _mixed_step_fused if _fused_step(sage) \
            else _mixed_step_reference
        return mixed(sched, sage, z, t, t_next, eps_u, eps_c, eps_prev,
                     t_prev, is_first, row_samplers)
    if _fused_step(sage) and sage.sampler == "dpmpp":
        a_t, s_t, a_n, s_n, lam, lam_p, lam_n = samplers.dpmpp_scalars(
            sched, t, t_next, t_prev)
        return dispatch.cfg_dpmpp_step(
            z, eps_u, eps_c, eps_prev, guidance=sage.guidance_scale,
            a_t=a_t, s_t=s_t, a_n=a_n, s_n=s_n,
            lam=lam, lam_p=lam_p, lam_n=lam_n, is_first=is_first,
            clip_x0=sage.clip_x0, impl="fused",
            interpret=sage.kernel_interpret)
    if _fused_step(sage):
        a_t, s_t, a_n, s_n = samplers.ddim_scalars(sched, t, t_next)
        z = dispatch.cfg_ddim_step(
            z, eps_u, eps_c, guidance=sage.guidance_scale,
            a_t=a_t, s_t=s_t, a_n=a_n, s_n=s_n, clip_x0=sage.clip_x0,
            impl="fused", interpret=sage.kernel_interpret)
        return z, eps_c
    eps = cfg_combine(eps_u, eps_c, sage.guidance_scale)
    z = _sampler_update(sched, sage, z, t, t_next, eps, eps_prev, t_prev,
                        is_first)
    return z, eps


class SampleCarry(NamedTuple):
    """Resumable sampler state between segment calls.

    ``z`` is (B, H, W, C) with B = K during the shared phase and B = K*N
    after :func:`fork_carry`; ``eps_prev`` (same shape) is the
    DPM-Solver++(2M) history (never read on the DDIM path); ``step_idx``
    is the *global* position on the DDIM grid — a traced int32 scalar, so
    segments of the same length share one compilation regardless of where
    on the grid they start.  In a packed super-batch (several groups
    stacked into one carry) ``step_idx`` is instead a per-row (B,) int32
    vector: each row advances from its own grid position.
    """
    z: jnp.ndarray
    eps_prev: jnp.ndarray
    step_idx: jnp.ndarray


def init_carry(key: jax.Array, K: int,
               latent_shape: Tuple[int, int, int]) -> SampleCarry:
    """Fresh trajectory start: shared init noise, empty history, step 0."""
    H, W, C = latent_shape
    z = jax.random.normal(key, (K, H, W, C), jnp.float32)
    return SampleCarry(z, jnp.zeros_like(z), jnp.int32(0))


def fork_carry(carry: SampleCarry, n_members: int) -> SampleCarry:
    """Branch point: broadcast the K group latents to (K*N) member rows.

    The solver history restarts at the fork (``branch_phase`` takes the
    warm-up path at ``fork_idx``), so ``eps_prev`` is zeroed — which also
    makes a trunk-cache restore exact: a cached ``(z_Ts, ...)`` forked by a
    different group reproduces the same branch trajectories regardless of
    the shared-phase history that produced it.
    """
    K, H, W, C = carry.z.shape
    zb = jnp.broadcast_to(carry.z[:, None], (K, n_members, H, W, C)
                          ).reshape(K * n_members, H, W, C)
    return SampleCarry(zb, jnp.zeros_like(zb), carry.step_idx)


def shared_phase(eps_fn: EpsFn, sched: Schedule, sage: SageConfig,
                 carry: SampleCarry, cbar: jnp.ndarray,
                 null_cond: jnp.ndarray, n_steps: int,
                 grid: Optional[jnp.ndarray] = None,
                 row_samplers: Optional[Sequence[str]] = None
                 ) -> SampleCarry:
    """Advance the group-trunk phase ``n_steps`` sampler steps.

    carry.z (K, H, W, C); cbar (K, Lc, dc) group-mean text features.
    ``n_steps`` is static (one jit bucket per segment length); the start
    position rides in ``carry.step_idx`` — a scalar, or a per-row (K,)
    vector when the rows are a packed stack of groups at different grid
    positions.  History warm-up fires at global step 0 only, so resuming
    mid-phase is exact.  ``grid`` overrides the default DDIM grid — 1-D
    (shared by all rows, e.g. a tier's own total_steps) or 2-D (K, L)
    per-row grids for stacks mixing step budgets; ``row_samplers``
    (static tuple) lets rows mix solvers (see :func:`_step_update`).
    """
    if n_steps <= 0:
        return carry
    carry = carry._replace(step_idx=jnp.asarray(carry.step_idx, jnp.int32))
    K = carry.z.shape[0]
    if grid is None:
        grid = jnp.asarray(ddim_timesteps(sched.T, sage.total_steps))
    else:
        grid = jnp.asarray(grid)
    sage, row_samplers = _norm_row_samplers(sage, row_samplers)

    def body(c: SampleCarry, _):
        z, eps_prev, i = c
        t, t_next = _grid_gather(grid, i), _grid_gather(grid, i + 1)
        tb = jnp.broadcast_to(t, (K,))
        eps_u, eps_c = _eps_pair(eps_fn, z, tb, cbar, null_cond)
        z, eps = _step_update(sched, sage, z, t, t_next, eps_u, eps_c,
                              eps_prev,
                              _grid_gather(grid, jnp.maximum(i - 1, 0)),
                              i == 0, row_samplers=row_samplers)
        return SampleCarry(z, eps, i + 1), None

    carry, _ = jax.lax.scan(body, carry, None, length=n_steps)
    return carry


def branch_phase(eps_fn: EpsFn, sched: Schedule, sage: SageConfig,
                 carry: SampleCarry, cond_flat: jnp.ndarray,
                 mask: jnp.ndarray, null_cond: jnp.ndarray, n_steps: int,
                 fork_idx: Union[int, jnp.ndarray],
                 grid: Optional[jnp.ndarray] = None,
                 row_samplers: Optional[Sequence[str]] = None
                 ) -> SampleCarry:
    """Advance the per-member phase ``n_steps`` steps after a fork.

    carry.z (K*N, H, W, C) from :func:`fork_carry`; cond_flat
    (K*N, Lc, dc) per-member text features; mask (K, N).  ``fork_idx`` is
    the global step at which this trajectory forked — the solver history
    warm-up fires exactly there (it may be traced: groups with different
    branch points share one compilation per segment length).  For a
    packed stack of groups, ``carry.step_idx`` and ``fork_idx`` are
    per-row (K*N,) vectors — one super-batch can mix a group at its fork
    (warming up) with groups mid-branch.  ``grid``/``row_samplers`` as in
    :func:`shared_phase` (2-D grids are (K*N, L) here — width-repeated
    per member row by ``packing.pack_grid``).
    """
    if n_steps <= 0:
        return carry
    carry = carry._replace(step_idx=jnp.asarray(carry.step_idx, jnp.int32))
    K, N = mask.shape
    if grid is None:
        grid = jnp.asarray(ddim_timesteps(sched.T, sage.total_steps))
    else:
        grid = jnp.asarray(grid)
    sage, row_samplers = _norm_row_samplers(sage, row_samplers)
    fork_idx = jnp.asarray(fork_idx, jnp.int32)

    def body(c: SampleCarry, _):
        z, eps_prev, i = c
        t, t_next = _grid_gather(grid, i), _grid_gather(grid, i + 1)
        if sage.shared_uncond_cfg:
            # uncond eval once per group on the group-mean trajectory proxy:
            # members share z only at the branch point, so per-member uncond
            # is approximated by the group-mean latent's uncond — exact at
            # i == fork_idx, approximate after.  Quality impact measured in
            # benchmarks/fig4_shared_steps.py.  The group eval is PACKED
            # into the same denoiser batch as the member-cond evals — one
            # eps_fn call of K + K*N instead of two sequential calls.
            gm_impl = "pallas" if _fused_step(sage) else "reference"
            zg = dispatch.group_mean(z.reshape(K, N, *z.shape[1:]), mask,
                                     impl=gm_impl,
                                     interpret=sage.kernel_interpret)
            zz = jnp.concatenate([zg, z], 0)            # (K + K*N, H, W, C)
            if jnp.ndim(t):
                # per-row t: members of a group share a step, so the
                # group-mean rows take their group's (first member's) t
                tt = jnp.concatenate([t.reshape(K, N)[:, 0], t], 0)
            else:
                tt = jnp.full((K + K * N,), t)
            null_shape = (K,) + null_cond.shape
            cc = jnp.concatenate(
                [jnp.broadcast_to(null_cond, null_shape), cond_flat], 0)
            eps = eps_fn(zz, tt, cc)
            eps_u = jnp.broadcast_to(eps[:K][:, None],
                                     (K, N) + z.shape[1:]
                                     ).reshape(z.shape)
            eps_c = eps[K:]
        else:
            tb = jnp.broadcast_to(t, (K * N,))
            eps_u, eps_c = _eps_pair(eps_fn, z, tb, cond_flat, null_cond)
        z, eps = _step_update(sched, sage, z, t, t_next, eps_u, eps_c,
                              eps_prev,
                              _grid_gather(grid, jnp.maximum(i - 1, 0)),
                              i == fork_idx,  # history restarts at the fork
                              row_samplers=row_samplers)
        return SampleCarry(z, eps, i + 1), None

    carry, _ = jax.lax.scan(body, carry, None, length=n_steps)
    return carry


def phase_split(total_steps: int, beta: float) -> Tuple[int, int]:
    """THE branch-point rule, in one place: share-ratio bucket ``beta``
    splits ``total_steps`` into ``(n_shared, n_branch)`` with
    ``n_branch = round(T * (1 - beta))``.  Every consumer — the streaming
    launch path, ``run_batch``'s beta buckets, and the trunk-cache
    ``beta_bucket`` compatibility key — derives its phase lengths here,
    so the split can never diverge between them (it is the bucket
    signature the packed ``run_batch`` path keys its segments on)."""
    n_branch = int(round(total_steps * (1.0 - beta)))
    return total_steps - n_branch, n_branch


def shared_phase_nfe(K: int, n_steps: int) -> float:
    """Denoiser evals for ``n_steps`` shared steps: the CFG pair per group."""
    return 2.0 * K * n_steps


def branch_phase_nfe(mask, n_steps: int, shared_uncond: bool):
    """Denoiser evals for ``n_steps`` branch steps of a (K, N) packing:
    2 per member, or member + one group-level uncond with the shared-uncond
    CFG (mask (K, N) — padding rows are masked out of the count).  Stays
    traceable (the engine jits :func:`shared_sample` whole)."""
    K = mask.shape[0]
    n_members = jnp.sum(mask)
    per_step = (n_members + K) if shared_uncond else 2.0 * n_members
    return per_step * n_steps


def shared_sample(eps_fn: EpsFn, sched: Schedule, sage: SageConfig,
                  key: jax.Array, cond_tokens: jnp.ndarray,
                  mask: jnp.ndarray, null_cond: jnp.ndarray,
                  latent_shape: Tuple[int, int, int],
                  branch_point: Optional[int] = None
                  ) -> Dict[str, jnp.ndarray]:
    """Run Alg. 1 for packed groups — thin wrapper over the segment API
    (one shared segment covering the whole trunk, one branch segment to
    t=0; the serving scheduler calls the same phases in S-step slices).

    cond_tokens (K, N, Lc, dc); mask (K, N); null_cond (Lc, dc).
    Returns {"latents": (K, N, H, W, C), "nfe": scalar}.
    """
    K, N = mask.shape
    T = sage.total_steps
    Ts = sage.branch_point if branch_point is None else branch_point
    n_shared = T - Ts

    cbar = group_mean(cond_tokens, mask)                    # (K, Lc, dc)
    carry = init_carry(key, K, latent_shape)
    carry = shared_phase(eps_fn, sched, sage, carry, cbar, null_cond,
                         n_shared)
    carry = fork_carry(carry, N)
    cm = cond_tokens.reshape(K * N, *cond_tokens.shape[2:])
    carry = branch_phase(eps_fn, sched, sage, carry, cm, mask, null_cond,
                         T - n_shared, fork_idx=n_shared)

    nfe = (shared_phase_nfe(K, n_shared)
           + branch_phase_nfe(mask, Ts, sage.shared_uncond_cfg))
    H, W, C = latent_shape
    return {"latents": carry.z.reshape(K, N, H, W, C), "nfe": nfe}


def independent_sample(eps_fn: EpsFn, sched: Schedule, sage: SageConfig,
                       key: jax.Array, cond_tokens: jnp.ndarray,
                       null_cond: jnp.ndarray,
                       latent_shape: Tuple[int, int, int]
                       ) -> Dict[str, jnp.ndarray]:
    """Baseline: conventional independent sampling (Fig. 1a)."""
    M = cond_tokens.shape[0]
    H, W, C = latent_shape
    grid = jnp.asarray(ddim_timesteps(sched.T, sage.total_steps))
    z = jax.random.normal(key, (M, H, W, C), jnp.float32)

    def step(carry, i):
        z, eps_prev = carry
        t, t_next = grid[i], grid[i + 1]
        tb = jnp.full((M,), t)
        eps_u, eps_c = _eps_pair(eps_fn, z, tb, cond_tokens, null_cond)
        z, eps = _step_update(sched, sage, z, t, t_next, eps_u, eps_c,
                              eps_prev, grid[jnp.maximum(i - 1, 0)], i == 0)
        return (z, eps), None

    (z, _), _ = jax.lax.scan(step, (z, jnp.zeros_like(z)),
                             jnp.arange(sage.total_steps))
    return {"latents": z, "nfe": 2 * M * sage.total_steps}
