"""Evaluation metrics (paper §3.1): FID, CLIP score, inter-group diversity.

Offline substitutes (DESIGN.md §2): no pretrained Inception/CLIP/AlexNet is
available, so each metric keeps the paper's *functional form* with a
deterministic feature extractor:

* FD-R   — Fréchet distance over fixed-seed random-conv features (relative
           comparator across sampling schemes, like FID);
* CLIP-P — cosine(text, image) through our contrastively-trained two-tower
           (models.text_encoder);
* DIV    — mean pairwise feature distance among images generated for the
           *same group* (the paper's inter-group LPIPS role): higher means
           branch phases actually diversified from the shared trunk.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# fixed random-conv feature extractor (FD-R / DIV backbone)
# ---------------------------------------------------------------------------

def _rf_params(seed: int = 7, chans=(16, 32, 64)):
    key = jax.random.PRNGKey(seed)
    ws = []
    cin = 3
    for i, c in enumerate(chans):
        k = jax.random.fold_in(key, i)
        ws.append(jax.random.normal(k, (3, 3, cin, c)) / np.sqrt(9 * cin))
        cin = c
    return ws


_RF = None


def random_features(images: jnp.ndarray) -> jnp.ndarray:
    """images (B,H,W,3) in [-1,1] -> (B, F) multi-scale features."""
    global _RF
    if _RF is None:
        _RF = _rf_params()
    feats = []
    h = images
    for w in _RF:
        h = jax.lax.conv_general_dilated(
            h, w.astype(h.dtype), (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jnp.tanh(h)
        feats.append(jnp.mean(h, axis=(1, 2)))
    return jnp.concatenate(feats, axis=-1)


def frechet_distance(feat_a: np.ndarray, feat_b: np.ndarray) -> float:
    """FD between Gaussian fits; tr sqrt(C1 C2) via eigenvalues."""
    a, b = np.asarray(feat_a, np.float64), np.asarray(feat_b, np.float64)
    mu1, mu2 = a.mean(0), b.mean(0)
    c1 = np.cov(a, rowvar=False) + 1e-6 * np.eye(a.shape[1])
    c2 = np.cov(b, rowvar=False) + 1e-6 * np.eye(b.shape[1])
    ev = np.linalg.eigvals(c1 @ c2)
    tr_sqrt = np.sum(np.sqrt(np.maximum(ev.real, 0.0)))
    return float(((mu1 - mu2) ** 2).sum() + np.trace(c1) + np.trace(c2)
                 - 2.0 * tr_sqrt)


def fd_r(real_images: jnp.ndarray, gen_images: jnp.ndarray) -> float:
    fa = np.asarray(random_features(real_images), np.float64)
    fb = np.asarray(random_features(gen_images), np.float64)
    return frechet_distance(fa, fb)


# ---------------------------------------------------------------------------
# CLIP-proxy
# ---------------------------------------------------------------------------

def clip_proxy(text_embeds: jnp.ndarray, image_embeds: jnp.ndarray) -> float:
    """Both L2-normalised (B,d); mean pairwise-matched cosine."""
    return float(jnp.mean(jnp.sum(text_embeds * image_embeds, axis=-1)))


# ---------------------------------------------------------------------------
# intra-group diversity (paper's inter-group LPIPS role)
# ---------------------------------------------------------------------------

def group_diversity(images: jnp.ndarray, mask: Optional[jnp.ndarray] = None
                    ) -> float:
    """images (K,N,H,W,3); mean pairwise feature L2 within each group."""
    K, N = images.shape[:2]
    feats = random_features(images.reshape(K * N, *images.shape[2:]))
    feats = feats.reshape(K, N, -1)
    d = jnp.linalg.norm(feats[:, :, None] - feats[:, None, :], axis=-1)
    if mask is None:
        pair = jnp.ones((K, N, N))
    else:
        pair = mask[:, :, None] * mask[:, None, :]
    pair = pair * (1.0 - jnp.eye(N)[None])
    return float(jnp.sum(d * pair) / jnp.maximum(jnp.sum(pair), 1e-6))
