"""ODE samplers over the VP schedule: DDIM (paper's sampler) and
DPM-Solver++(2M) as a faster alternative.

Both expose a per-step ``step(z, t_cur, t_next, eps)`` so the shared/branch
driver (core.shared_sampling) controls conditioning and step sharing.

``t``/``t_next`` may be scalars (every batch row at the same grid
position — the original contract) or (B,) vectors (rows at different
positions, the packed serving path): gathered schedule values broadcast
along the batch axis via ``bcast_rows``, so the per-row update applies
exactly the same arithmetic per element as the scalar one.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.schedule import Schedule
from repro.kernels._tiles import bcast_rows


def ddim_scalars(sched: Schedule, t: jnp.ndarray, t_next: jnp.ndarray):
    """Per-step (a_t, s_t, a_n, s_n) schedule gathers for one DDIM update.

    Exposed so the fused CFG+DDIM Pallas kernel receives the scalars
    directly (one (1, 8) SMEM-sized block) instead of re-deriving them
    from full-tensor schedule math inside the update."""
    return (sched.alpha(t), sched.sigma(t),
            sched.alpha(t_next), sched.sigma(t_next))


def dpmpp_scalars(sched: Schedule, t: jnp.ndarray, t_next: jnp.ndarray,
                  t_prev: jnp.ndarray):
    """Per-step scalars for one fused DPM-Solver++(2M) update.

    Returns ``(a_t, s_t, a_n, s_n, lam, lam_p, lam_n)`` — the schedule
    gathers plus the three log-SNR points the 2M extrapolation needs.
    Exposed so the fused CFG+DPM-Solver++ Pallas kernel receives everything
    in one (1, 16) SMEM-sized block instead of re-deriving lambda-space
    quantities from full-tensor schedule math inside the update; the same
    guard epsilons as :func:`dpmpp_2m_step` keep the two paths bit-aligned.
    """
    a_t, s_t = sched.alpha(t), sched.sigma(t)
    a_n, s_n = sched.alpha(t_next), sched.sigma(t_next)
    a_p, s_p = sched.alpha(t_prev), sched.sigma(t_prev)
    lam = jnp.log(jnp.maximum(a_t, 1e-6) / jnp.maximum(s_t, 1e-8))
    lam_n = jnp.log(jnp.maximum(a_n, 1e-6) / jnp.maximum(s_n, 1e-8))
    lam_p = jnp.log(jnp.maximum(a_p, 1e-6) / jnp.maximum(s_p, 1e-8))
    return a_t, s_t, a_n, s_n, lam, lam_p, lam_n


def ddim_step(sched: Schedule, z: jnp.ndarray, t: jnp.ndarray,
              t_next: jnp.ndarray, eps: jnp.ndarray,
              eta: float = 0.0, clip_x0: float = 0.0) -> jnp.ndarray:
    """Deterministic DDIM update (eta=0):   [Song et al., 2020]

        z0_hat = (z - sigma_t eps) / alpha_t
        z'     = alpha_{t'} z0_hat + sigma_{t'} eps

    clip_x0 > 0 enables static x0-thresholding (SD's clip_sample): near
    t = T alpha_t -> 0 and the 1/alpha blow-up otherwise dominates the
    trajectory, drowning per-member differences in the branch phase.
    """
    a_t, s_t = sched.alpha(t), sched.sigma(t)
    a_n, s_n = sched.alpha(t_next), sched.sigma(t_next)
    a_t, s_t, a_n, s_n = (bcast_rows(v, z.ndim) for v in (a_t, s_t,
                                                          a_n, s_n))
    z0 = (z - s_t * eps) / jnp.maximum(a_t, 1e-6)
    if clip_x0:
        z0 = jnp.clip(z0, -clip_x0, clip_x0)
    return a_n * z0 + s_n * eps


def dpmpp_2m_step(sched: Schedule, z: jnp.ndarray, t: jnp.ndarray,
                  t_next: jnp.ndarray, eps: jnp.ndarray,
                  eps_prev: Optional[jnp.ndarray] = None,
                  t_prev: Optional[jnp.ndarray] = None,
                  clip_x0: float = 0.0) -> jnp.ndarray:
    """DPM-Solver++(2M) in eps-parameterisation (data-pred internally).

    ``eps_prev is None`` (or == eps) reduces to the 1st-order update (the
    exponential-integrator form of DDIM).  [Lu et al., 2022]
    """
    a_t, s_t = sched.alpha(t), sched.sigma(t)
    a_n, s_n = sched.alpha(t_next), sched.sigma(t_next)
    a_t, s_t, a_n, s_n = (bcast_rows(v, z.ndim) for v in (a_t, s_t,
                                                          a_n, s_n))
    lam = jnp.log(jnp.maximum(a_t, 1e-6) / jnp.maximum(s_t, 1e-8))
    lam_n = jnp.log(jnp.maximum(a_n, 1e-6) / jnp.maximum(s_n, 1e-8))
    h = lam_n - lam

    def pred_x0(e):
        x0 = (z - s_t * e) / jnp.maximum(a_t, 1e-6)
        return jnp.clip(x0, -clip_x0, clip_x0) if clip_x0 else x0

    x0 = pred_x0(eps)
    if eps_prev is None:
        d = x0
    else:
        a_p, s_p = sched.alpha(t_prev), sched.sigma(t_prev)
        a_p, s_p = bcast_rows(a_p, z.ndim), bcast_rows(s_p, z.ndim)
        lam_p = jnp.log(jnp.maximum(a_p, 1e-6) / jnp.maximum(s_p, 1e-8))
        # 2M: linear extrapolation of the data prediction in lambda space
        r = (lam - lam_p) / jnp.where(jnp.abs(h) > 1e-8, h, 1e-8)
        x0_prev = pred_x0(eps_prev)
        d = x0 + (x0 - x0_prev) / (2.0 * jnp.maximum(r, 1e-8))
    return (s_n / jnp.maximum(s_t, 1e-8)) * z - a_n * jnp.expm1(-h) * d
