"""LoRA adapters (paper §3.1: SD v1.5 fine-tuned with LoRA).

Functional formulation: a LoRA pytree mirrors the base params on selected
2-D weights; ``merge(base, lora)`` produces effective params
W + (alpha/r) * A @ B for the forward pass.  Training optimises ONLY the
LoRA pytree (gradients flow through merge), so optimizer state is r-rank
sized — same memory story as the paper.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def default_filter(path: Tuple, leaf) -> bool:
    """Adapt matmul weights — 2-D, or 3-D with a leading stack dim (scanned
    layer blocks).  Skips norms/embeddings/positions/adaLN tables."""
    names = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
    if leaf.ndim not in (2, 3):
        return False
    if min(leaf.shape[-2:]) < 8:
        return False
    skip = ("embed", "pos", "adaln", "norm", "ln", "conv", "lam", "router")
    return not any(s in names for s in skip)


def init_lora(params: Params, rank: int, key,
              filt: Callable = default_filter) -> Params:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    lora_flat = {}
    for i, (path, leaf) in enumerate(flat):
        if filt(path, leaf):
            k = jax.random.fold_in(key, i)
            lead = leaf.shape[:-2]
            a = (jax.random.normal(k, lead + (leaf.shape[-2], rank),
                                   leaf.dtype)
                 / jnp.sqrt(leaf.shape[-2]))
            b = jnp.zeros(lead + (rank, leaf.shape[-1]), leaf.dtype)
            lora_flat[jax.tree_util.keystr(path)] = {"a": a, "b": b}
    return lora_flat


def merge(params: Params, lora: Params, alpha: float = 1.0) -> Params:
    """Effective params: W + (alpha/r) A@B on adapted leaves (batched matmul
    over any leading stack dims)."""

    def fix(path, leaf):
        key = jax.tree_util.keystr(path)
        if key in lora:
            ab = lora[key]
            r = ab["a"].shape[-1]
            delta = jnp.einsum("...ir,...ro->...io", ab["a"], ab["b"])
            return leaf + (alpha / r) * delta.astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params)


def n_params(lora: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))
