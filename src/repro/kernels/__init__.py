"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with shape/dtype handling) and ref.py (pure-jnp
oracle used by the allclose test sweeps).  Kernels target TPU VMEM tiling
and are validated on CPU with interpret=True.

* ddim_step       -- fused CFG combine + DDIM latent update (the per-step
                     elementwise tail of Alg. 1; fusing avoids repeated HBM
                     round trips per sampler step)
* dpmpp_step      -- fused CFG combine + DPM-Solver++(2M) update (lambda
                     extrapolation + history term in one pass; also emits
                     the combined eps for the solver's history carry)
* group_mean      -- masked segment mean over group members (the c-bar /
                     z-bar of Alg. 1/2) incl. the branch-point broadcast
* flash_attention -- blocked online-softmax attention (the DiT/transformer
                     hot loop; VMEM-tiled, MXU-aligned)
* ssd_scan        -- Mamba2 SSD intra-chunk tile (decay matrix stays in
                     VMEM; MXU-shaped Q=N=128 matmuls)
* dispatch        -- config/env-driven backend selector (naive | chunked |
                     pallas + interpret-mode resolution) that the model /
                     sampler hot paths call instead of hard-coding an impl

See README.md in this directory for backend selection and the
interpret-mode plumbing.
"""
