"""Shared plumbing for the elementwise step kernels: flatten arbitrary
latent shapes to padded (rows, BLOCK_C) tiles and pack per-step scalars
into one small fp32 block.  Used by ddim_step/ops.py and dpmpp_step/ops.py
so the tiling scheme can't drift between the two fused-step kernels."""
from __future__ import annotations

import jax.numpy as jnp


def tile_2d(block_r: int, block_c: int, *arrays):
    """Flatten each array to a zero-padded (rows_p, block_c) tile grid.

    All arrays must share a shape.  Returns ``(tiles, untile)`` where
    ``untile`` maps a (rows_p, block_c) result back to the original shape.
    """
    n = arrays[0].size
    orig_shape = arrays[0].shape
    rows = -(-n // block_c)
    rows_p = -(-rows // block_r) * block_r
    pad = rows_p * block_c - n

    def to2d(x):
        assert x.shape == orig_shape, (x.shape, orig_shape)
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows_p, block_c)

    def untile(x):
        return x.reshape(-1)[:n].reshape(orig_shape)

    return [to2d(x) for x in arrays], untile


def scalar_block(values, width: int):
    """Pack per-step scalars (python floats or traced jnp scalars) into a
    zero-padded (1, width) fp32 block for an SMEM-sized BlockSpec."""
    assert len(values) <= width, (len(values), width)
    block = jnp.zeros((1, width), jnp.float32)
    return block.at[0, :len(values)].set(
        jnp.stack([jnp.asarray(v, jnp.float32) for v in values]))
