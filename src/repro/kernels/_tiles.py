"""Shared plumbing for the elementwise step kernels: flatten arbitrary
latent shapes to padded (rows, BLOCK_C) tiles and pack per-step scalars
into one small fp32 block.  Used by ddim_step/ops.py and dpmpp_step/ops.py
so the tiling scheme can't drift between the two fused-step kernels.

Two tiling regimes:

* :func:`tile_2d` — the whole batch flattened into one (rows_p, block_c)
  grid, for steps where every batch row shares ONE scalar set (the
  original per-group execution model);
* :func:`tile_rows` + :func:`scalar_rows` — each batch element tiled
  separately to (B, rows_p, block_c) with a (B, width) scalar block, for
  the packed serving path where rows belong to different groups at
  different grid positions and therefore carry different step scalars.
"""
from __future__ import annotations

import jax.numpy as jnp


def tile_2d(block_r: int, block_c: int, *arrays):
    """Flatten each array to a zero-padded (rows_p, block_c) tile grid.

    All arrays must share a shape.  Returns ``(tiles, untile)`` where
    ``untile`` maps a (rows_p, block_c) result back to the original shape.
    """
    n = arrays[0].size
    orig_shape = arrays[0].shape
    rows = -(-n // block_c)
    rows_p = -(-rows // block_r) * block_r
    pad = rows_p * block_c - n

    def to2d(x):
        assert x.shape == orig_shape, (x.shape, orig_shape)
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows_p, block_c)

    def untile(x):
        return x.reshape(-1)[:n].reshape(orig_shape)

    return [to2d(x) for x in arrays], untile


def per_row_scalars(*scalars) -> bool:
    """True if any step scalar carries a batch axis — the routing
    predicate both fused-step ops use to choose the per-row tiling
    regime over the broadcast one."""
    return any(jnp.ndim(s) >= 1 for s in scalars)


def bcast_rows(s, ndim: int):
    """Align a per-row step scalar for broadcasting against a (B, ...)
    latent of rank ``ndim``: a (B,) vector gains trailing singleton axes,
    a plain scalar passes through untouched.  One home for the rule so the
    reference step math and the sampler twins broadcast identically."""
    s = jnp.asarray(s)
    if s.ndim == 0:
        return s
    return s.reshape(s.shape + (1,) * (ndim - s.ndim))


def row_block(n_per_row: int, block_c: int, block_r_max: int) -> int:
    """Row-tile height for per-row tiling: enough BLOCK_C-lanes rows to
    hold one batch element, rounded up to the fp32 sublane quantum (8),
    capped at the kernel's max block height.  Keeping the block close to
    the element size avoids the 2-D scheme's worst case (a tiny element
    padded to a full 256-row tile *per batch row*)."""
    rows = -(-n_per_row // block_c)
    return min(block_r_max, -(-rows // 8) * 8)


def tile_rows(block_r: int, block_c: int, *arrays):
    """Flatten each (B, ...) array to a zero-padded (B, rows_p, block_c)
    per-element tile grid (the per-row twin of :func:`tile_2d`).

    All arrays must share a shape.  Returns ``(tiles, untile)`` where
    ``untile`` maps a (B, rows_p, block_c) result back to the original
    shape.
    """
    orig_shape = arrays[0].shape
    B = orig_shape[0]
    n = 1
    for d in orig_shape[1:]:
        n *= d
    rows = -(-n // block_c)
    rows_p = -(-rows // block_r) * block_r
    pad = rows_p * block_c - n

    def to3d(x):
        assert x.shape == orig_shape, (x.shape, orig_shape)
        return jnp.pad(x.reshape(B, -1), ((0, 0), (0, pad))
                       ).reshape(B, rows_p, block_c)

    def untile(x):
        return x.reshape(B, -1)[:, :n].reshape(orig_shape)

    return [to3d(x) for x in arrays], untile


def scalar_rows(values, width: int, rows: int):
    """Pack per-row step scalars into a (rows, width) fp32 block — one
    scalar row per batch element (the per-row twin of
    :func:`scalar_block`).  Each value may be a python float, a traced
    scalar (broadcast to every row) or a (rows,) vector."""
    assert len(values) <= width, (len(values), width)
    cols = [jnp.broadcast_to(jnp.asarray(v, jnp.float32), (rows,))
            for v in values]
    block = jnp.zeros((rows, width), jnp.float32)
    return block.at[:, :len(values)].set(jnp.stack(cols, axis=1))


def scalar_block(values, width: int):
    """Pack per-step scalars (python floats or traced jnp scalars) into a
    zero-padded (1, width) fp32 block for an SMEM-sized BlockSpec."""
    assert len(values) <= width, (len(values), width)
    block = jnp.zeros((1, width), jnp.float32)
    return block.at[0, :len(values)].set(
        jnp.stack([jnp.asarray(v, jnp.float32) for v in values]))
