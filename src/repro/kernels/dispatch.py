"""Kernel backend dispatch — one switch between reference jnp math, the
chunked online-softmax twin, and the Pallas TPU kernels.

Every hot-path site (DiT attention, the CFG+DDIM sampler update, the
group-mean reductions of Alg. 1) routes through this module instead of
hard-coding an implementation, so a single config/env knob moves the whole
sampling loop between backends:

* ``impl`` — ``"naive"`` (materialised scores / separate elementwise
  passes), ``"chunked"`` (jnp online-softmax scan), ``"pallas"`` (the
  kernels under ``repro.kernels``).
* ``interpret`` — Pallas interpret-mode plumbing.  ``"auto"`` (default)
  runs interpret mode off-TPU (CPU tests exercise the kernel bodies) and
  compiled mode on TPU — previously ``interpret=True`` was hard-coded at
  every call site, so the kernels never actually compiled.  The env var
  ``REPRO_KERNEL_INTERPRET=on|off`` overrides everything (useful to force
  interpret mode when debugging a miscompile on device).

Fallbacks are explicit and conservative: the only shapes the flash kernel
does not cover — ``head_dim > 256`` and non-causal sliding windows — drop
to the chunked path rather than silently computing the wrong mask.

Dispatch attribution (PR 8): every route decision can be recorded in the
module-level :data:`DISPATCH_LOG` — (op, impl requested, impl chosen,
fallback reason, shape bucket) → decision count — turning the README's
static fallback matrix into live telemetry.  Off by default (a plain
boolean test per dispatch); ``serve_shared.py --metrics`` and the
telemetry tests flip it on.  Under ``jax.jit`` a dispatch records once
per *trace* (compilation), not per device launch — the log counts route
decisions, which is exactly what the fallback matrix needs.
"""
from __future__ import annotations

import math
import os
from typing import Dict, Iterable, List, Optional, Tuple, Union

import jax

ATTN_IMPLS = ("naive", "chunked", "pallas")
STEP_IMPLS = ("reference", "fused")

InterpretLike = Union[None, bool, str]


class DispatchLog:
    """Route-decision counter for kernel dispatch attribution.

    Keyed by ``(op, requested, chosen, reason, shape)``; ``reason`` is
    ``"requested"`` when the chosen impl is what the caller asked for,
    else the concrete fallback cause (``"head_dim>256"``,
    ``"noncausal_window"``).  Disabled by default so the hot path pays
    one ``if`` per dispatch."""

    __slots__ = ("enabled", "routes")

    def __init__(self) -> None:
        self.enabled = False
        self.routes: Dict[Tuple[str, str, str, str, str], int] = {}

    def record(self, op: str, requested: str, chosen: str, reason: str,
               shape: str) -> None:
        key = (op, requested, chosen, reason, shape)
        self.routes[key] = self.routes.get(key, 0) + 1

    def reset(self) -> None:
        self.routes.clear()

    def snapshot(self) -> List[Dict[str, object]]:
        """Rows sorted for stable output: one dict per distinct route."""
        return [
            {"op": op, "requested": req, "chosen": chosen,
             "reason": reason, "shape": shape, "count": n}
            for (op, req, chosen, reason, shape), n
            in sorted(self.routes.items())]

    def fallbacks(self) -> List[Dict[str, object]]:
        """Only the routes where chosen != requested — the live version
        of the README fallback matrix."""
        return [r for r in self.snapshot() if r["reason"] != "requested"]

    def prometheus_samples(self) -> Iterable[
            Tuple[str, Dict[str, str], float, str]]:
        """(name, labels, value, kind) tuples for
        ``MetricsRegistry.collector``."""
        for (op, req, chosen, reason, shape), n in sorted(
                self.routes.items()):
            yield ("kernel_dispatch",
                   {"op": op, "requested": req, "chosen": chosen,
                    "reason": reason, "shape": shape}, float(n), "counter")


#: process-wide log; enable with ``DISPATCH_LOG.enabled = True``
DISPATCH_LOG = DispatchLog()


def _attn_shape_bucket(q: jax.Array, k: jax.Array) -> str:
    B, Sq, H, hd = q.shape
    return f"b{B}s{Sq}x{k.shape[1]}h{H}d{hd}"


def resolve_interpret(setting: InterpretLike = "auto") -> bool:
    """Resolve an interpret-mode setting to a concrete bool.

    Priority: REPRO_KERNEL_INTERPRET env var > explicit on/off setting >
    auto (interpret unless running on TPU).
    """
    env = os.environ.get("REPRO_KERNEL_INTERPRET", "").strip().lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    if env:
        # a typo'd override silently doing nothing is worst exactly when
        # someone is debugging a miscompile — fail loudly instead
        raise ValueError(
            f"REPRO_KERNEL_INTERPRET={env!r} not understood; use on|off")
    if setting in (True, "on", "1", "true"):
        return True
    if setting in (False, "off", "0", "false"):
        return False
    if setting not in (None, "auto", ""):
        raise ValueError(f"unknown interpret setting {setting!r}")
    return jax.default_backend() != "tpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              impl: str = "naive", causal: bool = False, window: int = 0,
              block: int = 1024, scale: Optional[float] = None,
              interpret: InterpretLike = "auto") -> jax.Array:
    """Backend-dispatched attention.  q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd).

    ``pallas`` streams K/V blocks through the flash kernel (GQA folded
    into the batch index map, padded keys masked via seq_k; sliding
    windows trim the K grid via the index map; head_dim <= 256 runs the
    two-lane-tile D variant); ``chunked`` is its jnp twin; ``naive``
    materialises the (Sq, Sk) scores.
    """
    from repro.models.layers import attend, attend_chunked, causal_mask

    if impl not in ATTN_IMPLS:
        raise ValueError(f"unknown attn impl {impl!r}; one of {ATTN_IMPLS}")
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    log = DISPATCH_LOG
    if (impl == "pallas" and q.shape[-1] <= 256
            and (window == 0 or causal)):
        if log.enabled:
            log.record("attention", impl, "pallas", "requested",
                       _attn_shape_bucket(q, k))
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale,
                               interpret=resolve_interpret(interpret))
    if impl in ("chunked", "pallas"):
        # pallas lands here only for head_dim > 256 / non-causal window
        if log.enabled:
            reason = "requested"
            if impl == "pallas":
                reason = ("head_dim>256" if q.shape[-1] > 256
                          else "noncausal_window")
            log.record("attention", impl, "chunked", reason,
                       _attn_shape_bucket(q, k))
        return attend_chunked(q, k, v, causal=causal, window=window,
                              scale=scale, block=block)
    if log.enabled:
        log.record("attention", impl, "naive", "requested",
                   _attn_shape_bucket(q, k))
    if causal:
        mask = causal_mask(q.shape[1], k.shape[1], window=window)
    elif window:
        # look-back limit without causality — match the chunked twin's
        # semantics instead of silently ignoring the window
        import jax.numpy as jnp
        qi = jnp.arange(q.shape[1])[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        mask = (ki > qi - window)[None, None, None]
    else:
        mask = None
    return attend(q, k, v, mask, scale)


def cfg_ddim_step(z: jax.Array, eps_u: jax.Array, eps_c: jax.Array, *,
                  guidance, a_t, s_t, a_n, s_n, clip_x0: float = 0.0,
                  impl: str = "reference",
                  interpret: InterpretLike = "auto") -> jax.Array:
    """CFG combine + DDIM update: one fused HBM pass on the pallas path,
    reference jnp math otherwise.  Scalars may be traced (per scan step)."""
    if impl not in STEP_IMPLS:
        raise ValueError(f"unknown step impl {impl!r}; one of {STEP_IMPLS}")
    if DISPATCH_LOG.enabled:
        DISPATCH_LOG.record("cfg_ddim_step", impl, impl, "requested",
                            "x".join(str(d) for d in z.shape))
    if impl == "fused":
        from repro.kernels.ddim_step.ops import fused_cfg_ddim_step
        return fused_cfg_ddim_step(z, eps_u, eps_c, guidance, a_t, s_t,
                                   a_n, s_n, clip_x0=clip_x0,
                                   interpret=resolve_interpret(interpret))
    from repro.kernels.ddim_step.ref import fused_cfg_ddim_step_ref
    return fused_cfg_ddim_step_ref(z, eps_u, eps_c, guidance, a_t, s_t,
                                   a_n, s_n, clip_x0=clip_x0)


def cfg_dpmpp_step(z: jax.Array, eps_u: jax.Array, eps_c: jax.Array,
                   eps_prev: jax.Array, *, guidance, a_t, s_t, a_n, s_n,
                   lam, lam_p, lam_n, is_first, clip_x0: float = 0.0,
                   impl: str = "reference",
                   interpret: InterpretLike = "auto"):
    """CFG combine + DPM-Solver++(2M) update -> ``(z_next, eps_combined)``.

    One fused HBM pass on the pallas path (read 4 tiles, write 2 — the
    combined eps comes back for the solver's history carry); reference jnp
    math otherwise.  Scalars come from ``samplers.dpmpp_scalars`` and may
    be traced (per scan step); ``is_first`` flags the history-warmup step
    (first step and the branch fork), where the extrapolation term is
    exactly zero."""
    if impl not in STEP_IMPLS:
        raise ValueError(f"unknown step impl {impl!r}; one of {STEP_IMPLS}")
    if DISPATCH_LOG.enabled:
        DISPATCH_LOG.record("cfg_dpmpp_step", impl, impl, "requested",
                            "x".join(str(d) for d in z.shape))
    if impl == "fused":
        from repro.kernels.dpmpp_step.ops import fused_cfg_dpmpp_step
        return fused_cfg_dpmpp_step(z, eps_u, eps_c, eps_prev, guidance,
                                    a_t, s_t, a_n, s_n, lam, lam_p, lam_n,
                                    is_first, clip_x0=clip_x0,
                                    interpret=resolve_interpret(interpret))
    from repro.kernels.dpmpp_step.ref import fused_cfg_dpmpp_step_ref
    return fused_cfg_dpmpp_step_ref(z, eps_u, eps_c, eps_prev, guidance,
                                    a_t, s_t, a_n, s_n, lam, lam_p, lam_n,
                                    is_first, clip_x0=clip_x0)


def group_mean(x: jax.Array, mask: jax.Array, *, impl: str = "reference",
               interpret: InterpretLike = "auto") -> jax.Array:
    """Masked mean over the member axis.  x (K,N,...), mask (K,N)."""
    if impl not in ("reference", "pallas", "fused"):
        raise ValueError(f"unknown group_mean impl {impl!r}")
    if DISPATCH_LOG.enabled:
        chosen = "pallas" if impl in ("pallas", "fused") else "reference"
        DISPATCH_LOG.record("group_mean", impl, chosen, "requested",
                            "x".join(str(d) for d in x.shape))
    if impl in ("pallas", "fused"):
        from repro.kernels.group_mean.ops import masked_group_mean
        return masked_group_mean(x, mask,
                                 interpret=resolve_interpret(interpret))
    from repro.kernels.group_mean.ref import masked_group_mean_ref
    return masked_group_mean_ref(x, mask)
