"""Kernel backend dispatch — one switch between reference jnp math, the
chunked online-softmax twin, and the Pallas TPU kernels.

Every hot-path site (DiT attention, the CFG+DDIM sampler update, the
group-mean reductions of Alg. 1) routes through this module instead of
hard-coding an implementation, so a single config/env knob moves the whole
sampling loop between backends:

* ``impl`` — ``"naive"`` (materialised scores / separate elementwise
  passes), ``"chunked"`` (jnp online-softmax scan), ``"pallas"`` (the
  kernels under ``repro.kernels``).
* ``interpret`` — Pallas interpret-mode plumbing.  ``"auto"`` (default)
  runs interpret mode off-TPU (CPU tests exercise the kernel bodies) and
  compiled mode on TPU — previously ``interpret=True`` was hard-coded at
  every call site, so the kernels never actually compiled.  The env var
  ``REPRO_KERNEL_INTERPRET=on|off`` overrides everything (useful to force
  interpret mode when debugging a miscompile on device).

Fallbacks are explicit and conservative: sliding-window attention has no
Pallas kernel yet, so ``impl="pallas"`` with ``window > 0`` drops to the
chunked path rather than silently computing the wrong mask.
"""
from __future__ import annotations

import math
import os
from typing import Optional, Union

import jax

ATTN_IMPLS = ("naive", "chunked", "pallas")
STEP_IMPLS = ("reference", "fused")

InterpretLike = Union[None, bool, str]


def resolve_interpret(setting: InterpretLike = "auto") -> bool:
    """Resolve an interpret-mode setting to a concrete bool.

    Priority: REPRO_KERNEL_INTERPRET env var > explicit on/off setting >
    auto (interpret unless running on TPU).
    """
    env = os.environ.get("REPRO_KERNEL_INTERPRET", "").strip().lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    if env:
        # a typo'd override silently doing nothing is worst exactly when
        # someone is debugging a miscompile — fail loudly instead
        raise ValueError(
            f"REPRO_KERNEL_INTERPRET={env!r} not understood; use on|off")
    if setting in (True, "on", "1", "true"):
        return True
    if setting in (False, "off", "0", "false"):
        return False
    if setting not in (None, "auto", ""):
        raise ValueError(f"unknown interpret setting {setting!r}")
    return jax.default_backend() != "tpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              impl: str = "naive", causal: bool = False, window: int = 0,
              block: int = 1024, scale: Optional[float] = None,
              interpret: InterpretLike = "auto") -> jax.Array:
    """Backend-dispatched attention.  q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd).

    ``pallas`` streams K/V blocks through the flash kernel (GQA folded
    into the batch index map, padded keys masked via seq_k); ``chunked``
    is its jnp twin; ``naive`` materialises the (Sq, Sk) scores.
    """
    from repro.models.layers import attend, attend_chunked, causal_mask

    if impl not in ATTN_IMPLS:
        raise ValueError(f"unknown attn impl {impl!r}; one of {ATTN_IMPLS}")
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    if impl == "pallas" and window == 0 and q.shape[-1] <= 128:
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, scale=scale,
                               interpret=resolve_interpret(interpret))
    if impl in ("chunked", "pallas"):
        # pallas lands here only for unsupported shapes (window / wide hd)
        return attend_chunked(q, k, v, causal=causal, window=window,
                              scale=scale, block=block)
    mask = (causal_mask(q.shape[1], k.shape[1], window=window)
            if causal else None)
    return attend(q, k, v, mask, scale)


def cfg_ddim_step(z: jax.Array, eps_u: jax.Array, eps_c: jax.Array, *,
                  guidance, a_t, s_t, a_n, s_n, clip_x0: float = 0.0,
                  impl: str = "reference",
                  interpret: InterpretLike = "auto") -> jax.Array:
    """CFG combine + DDIM update: one fused HBM pass on the pallas path,
    reference jnp math otherwise.  Scalars may be traced (per scan step)."""
    if impl not in STEP_IMPLS:
        raise ValueError(f"unknown step impl {impl!r}; one of {STEP_IMPLS}")
    if impl == "fused":
        from repro.kernels.ddim_step.ops import fused_cfg_ddim_step
        return fused_cfg_ddim_step(z, eps_u, eps_c, guidance, a_t, s_t,
                                   a_n, s_n, clip_x0=clip_x0,
                                   interpret=resolve_interpret(interpret))
    from repro.kernels.ddim_step.ref import fused_cfg_ddim_step_ref
    return fused_cfg_ddim_step_ref(z, eps_u, eps_c, guidance, a_t, s_t,
                                   a_n, s_n, clip_x0=clip_x0)


def group_mean(x: jax.Array, mask: jax.Array, *, impl: str = "reference",
               interpret: InterpretLike = "auto") -> jax.Array:
    """Masked mean over the member axis.  x (K,N,...), mask (K,N)."""
    if impl not in ("reference", "pallas", "fused"):
        raise ValueError(f"unknown group_mean impl {impl!r}")
    if impl in ("pallas", "fused"):
        from repro.kernels.group_mean.ops import masked_group_mean
        return masked_group_mean(x, mask,
                                 interpret=resolve_interpret(interpret))
    from repro.kernels.group_mean.ref import masked_group_mean_ref
    return masked_group_mean_ref(x, mask)
