"""Pure-jnp oracle for the fused CFG+DDIM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_cfg_ddim_step_ref(z, eps_u, eps_c, guidance: float,
                            a_t: float, s_t: float, a_n: float, s_n: float,
                            clip_x0: float = 0.0):
    zf = z.astype(jnp.float32)
    eps = (eps_u + guidance * (eps_c - eps_u)).astype(jnp.float32)
    z0 = (zf - s_t * eps) / jnp.maximum(a_t, 1e-6)
    if clip_x0:
        z0 = jnp.clip(z0, -clip_x0, clip_x0)
    return (a_n * z0 + s_n * eps).astype(z.dtype)
