"""Pure-jnp oracle for the fused CFG+DDIM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_cfg_ddim_step_ref(z, eps_u, eps_c, guidance: float,
                            a_t: float, s_t: float, a_n: float, s_n: float):
    zf = z.astype(jnp.float32)
    eps = (eps_u + guidance * (eps_c - eps_u)).astype(jnp.float32)
    z0 = (zf - s_t * eps) / a_t
    return (a_n * z0 + s_n * eps).astype(z.dtype)
