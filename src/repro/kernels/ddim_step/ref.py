"""Pure-jnp oracle for the fused CFG+DDIM kernel.

Step scalars may be plain scalars or (B,) per-row vectors (the packed
serving path) — vectors broadcast along the batch axis via ``bcast_rows``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels._tiles import bcast_rows


def fused_cfg_ddim_step_ref(z, eps_u, eps_c, guidance,
                            a_t, s_t, a_n, s_n, clip_x0: float = 0.0):
    a_t, s_t, a_n, s_n = (bcast_rows(v, z.ndim) for v in (a_t, s_t,
                                                          a_n, s_n))
    zf = z.astype(jnp.float32)
    eps = (eps_u + guidance * (eps_c - eps_u)).astype(jnp.float32)
    z0 = (zf - s_t * eps) / jnp.maximum(a_t, 1e-6)
    if clip_x0:
        z0 = jnp.clip(z0, -clip_x0, clip_x0)
    return (a_n * z0 + s_n * eps).astype(z.dtype)
