"""Public wrapper: arbitrary latent shapes -> padded tiles -> kernel.

Scalars with a batch axis ((B,) vectors, as produced by gathering the
schedule at a per-row timestep) select the per-row kernel launch; plain
scalars keep the original broadcast launch.  Both run the same kernel
body, so the two paths cannot drift numerically.

Mixed-sampler packs (rows alternating ddim/dpmpp in one stacked launch)
call this wrapper on a static *gathered sub-batch* of the ddim rows and
scatter the result back — never on the full stack with a select.
Computing both solvers' updates over all rows and ``jnp.where``-choosing
is value-equal but not bitwise-safe: XLA fuses the combined expression
graph differently (CSE / fma reassociation) than the solo graph, so the
last bit drifts from the per-group oracle.  Gather/scatter keeps each
row's expression tree literally the solo one."""
from __future__ import annotations

from repro.kernels._tiles import (per_row_scalars, row_block, scalar_block,
                                  scalar_rows, tile_2d, tile_rows)
from repro.kernels.ddim_step.ddim_step import (BLOCK_C, BLOCK_R,
                                               ddim_step_2d, ddim_step_rows)


def fused_cfg_ddim_step(z, eps_u, eps_c, guidance, a_t, s_t, a_n, s_n,
                        interpret: bool | None = None,
                        clip_x0: float = 0.0):
    """Fused CFG + DDIM update for latents of any shape (B, ...).

    The step scalars (guidance, a_t, s_t, a_n, s_n, clip_x0) may be python
    floats or traced jnp scalars — e.g. ``schedule.alpha(t)`` gathered per
    scan step — and ride to the kernel in one (1, 8) block.  Any of them
    may instead be a (B,) vector (rows at different grid positions, the
    packed serving path): the update then launches the per-row variant
    with a (B, 8) scalar block.  clip_x0 > 0 enables the sampler's
    x0-thresholding; ``interpret=None`` resolves via dispatch (env
    override, else compiled only on TPU).
    """
    assert z.shape == eps_u.shape == eps_c.shape
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    # layout must match the kernel's scal_ref reads (see ddim_step.py)
    values = (guidance, a_t, s_t, a_n, s_n, clip_x0)
    if per_row_scalars(*values):
        n = z[0].size
        br = row_block(n, BLOCK_C, BLOCK_R)
        tiles, untile = tile_rows(br, BLOCK_C, z, eps_u, eps_c)
        scal = scalar_rows(values, 8, z.shape[0])
        return untile(ddim_step_rows(scal, *tiles, block_r=br,
                                     interpret=interpret))
    tiles, untile = tile_2d(BLOCK_R, BLOCK_C, z, eps_u, eps_c)
    scal = scalar_block(values, 8)
    return untile(ddim_step_2d(scal, *tiles, interpret=interpret))
