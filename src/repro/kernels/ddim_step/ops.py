"""Public wrapper: arbitrary latent shapes -> padded 2-D tiles -> kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ddim_step.ddim_step import (BLOCK_C, BLOCK_R, ddim_step_2d)


def fused_cfg_ddim_step(z, eps_u, eps_c, guidance, a_t, s_t, a_n, s_n,
                        interpret: bool = True):
    """Fused CFG + DDIM update for latents of any shape (B, ...)."""
    assert z.shape == eps_u.shape == eps_c.shape
    orig_shape, n = z.shape, z.size
    C = BLOCK_C
    rows = -(-n // C)
    rows_p = -(-rows // BLOCK_R) * BLOCK_R
    pad = rows_p * C - n

    def to2d(x):
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows_p, C)

    scal = jnp.zeros((1, 8), jnp.float32)
    scal = scal.at[0, :5].set(
        jnp.asarray([guidance, a_t, s_t, a_n, s_n], jnp.float32))
    out = ddim_step_2d(scal, to2d(z), to2d(eps_u), to2d(eps_c),
                       interpret=interpret)
    return out.reshape(-1)[:n].reshape(orig_shape)
