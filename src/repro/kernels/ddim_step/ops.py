"""Public wrapper: arbitrary latent shapes -> padded 2-D tiles -> kernel."""
from __future__ import annotations

from repro.kernels._tiles import scalar_block, tile_2d
from repro.kernels.ddim_step.ddim_step import (BLOCK_C, BLOCK_R, ddim_step_2d)


def fused_cfg_ddim_step(z, eps_u, eps_c, guidance, a_t, s_t, a_n, s_n,
                        interpret: bool | None = None,
                        clip_x0: float = 0.0):
    """Fused CFG + DDIM update for latents of any shape (B, ...).

    The step scalars (guidance, a_t, s_t, a_n, s_n, clip_x0) may be python
    floats or traced jnp scalars — e.g. ``schedule.alpha(t)`` gathered per
    scan step — and ride to the kernel in one (1, 8) block.  clip_x0 > 0
    enables the sampler's x0-thresholding; ``interpret=None`` resolves via
    dispatch (env override, else compiled only on TPU).
    """
    assert z.shape == eps_u.shape == eps_c.shape
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    tiles, untile = tile_2d(BLOCK_R, BLOCK_C, z, eps_u, eps_c)
    # layout must match the kernel's scal_ref reads (see ddim_step.py)
    scal = scalar_block((guidance, a_t, s_t, a_n, s_n, clip_x0), 8)
    return untile(ddim_step_2d(scal, *tiles, interpret=interpret))
