"""Public wrapper: arbitrary latent shapes -> padded 2-D tiles -> kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ddim_step.ddim_step import (BLOCK_C, BLOCK_R, ddim_step_2d)


def fused_cfg_ddim_step(z, eps_u, eps_c, guidance, a_t, s_t, a_n, s_n,
                        interpret: bool | None = None,
                        clip_x0: float = 0.0):
    """Fused CFG + DDIM update for latents of any shape (B, ...).

    The step scalars (guidance, a_t, s_t, a_n, s_n, clip_x0) may be python
    floats or traced jnp scalars — e.g. ``schedule.alpha(t)`` gathered per
    scan step — and ride to the kernel in one (1, 8) block.  clip_x0 > 0
    enables the sampler's x0-thresholding; ``interpret=None`` resolves via
    dispatch (env override, else compiled only on TPU).
    """
    assert z.shape == eps_u.shape == eps_c.shape
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    orig_shape, n = z.shape, z.size
    C = BLOCK_C
    rows = -(-n // C)
    rows_p = -(-rows // BLOCK_R) * BLOCK_R
    pad = rows_p * C - n

    def to2d(x):
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows_p, C)

    scal = jnp.zeros((1, 8), jnp.float32)
    scal = scal.at[0, :6].set(
        jnp.stack([jnp.asarray(v, jnp.float32) for v in
                   (guidance, a_t, s_t, a_n, s_n, clip_x0)]))
    out = ddim_step_2d(scal, to2d(z), to2d(eps_u), to2d(eps_c),
                       interpret=interpret)
    return out.reshape(-1)[:n].reshape(orig_shape)
