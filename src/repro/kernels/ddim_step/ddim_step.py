"""Fused CFG + DDIM update Pallas kernel.

Per sampler step SAGE (like any CFG diffusion sampler) computes

    eps = eps_u + w (eps_c - eps_u)
    z0  = (z - sigma_t eps) / alpha_t
    z'  = alpha_n z0 + sigma_n eps

Unfused, that is 3 elementwise passes over 3 latent-sized tensors (z,
eps_u, eps_c) -> 5 HBM round trips.  The kernel computes z' in one pass:
read 3 tiles, write 1.  Latents are flattened to (rows, lanes) tiles
(lane dim a multiple of 128 for the VPU); the 5 step scalars ride in a
(1, 8)-padded block mapped to every grid point.

Two launch shapes share the same kernel body:

* :func:`ddim_step_2d` — whole batch as one (rows, lanes) grid, ONE
  scalar row broadcast to every tile (per-group execution: all rows sit
  at the same grid position);
* :func:`ddim_step_rows` — (B, rows, lanes) grid with a (B, 8) scalar
  block indexed by the batch grid axis, so every row carries its OWN
  (a_t, s_t, a_n, s_n) — the packed serving path, where one super-batch
  mixes groups at different positions on the DDIM grid.

VMEM budget: 4 tiles x block(256, 256) x 4B = 1 MB  << 16 MB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 256


def _kernel(scal_ref, z_ref, eu_ref, ec_ref, out_ref):
    w = scal_ref[0, 0]
    a_t, s_t = scal_ref[0, 1], scal_ref[0, 2]
    a_n, s_n = scal_ref[0, 3], scal_ref[0, 4]
    clip = scal_ref[0, 5]
    z = z_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    ec = ec_ref[...].astype(jnp.float32)
    eps = eu + w * (ec - eu)
    z0 = (z - s_t * eps) / jnp.maximum(a_t, 1e-6)
    # static x0-thresholding (matches samplers.ddim_step); clip == 0 -> off
    z0 = jnp.where(clip > 0.0, jnp.clip(z0, -clip, clip), z0)
    out_ref[...] = (a_n * z0 + s_n * eps).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ddim_step_2d(scalars, z, eps_u, eps_c, interpret: bool = True):
    """z/eps_u/eps_c (R, C), R % BLOCK_R == 0 and C % BLOCK_C == 0;
    scalars (1, 8) f32 = [guidance, a_t, s_t, a_n, s_n, clip_x0, 0, 0]."""
    R, C = z.shape
    grid = (R // BLOCK_R, C // BLOCK_C)
    tile = pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j))
    scal = pl.BlockSpec((1, 8), lambda i, j: (0, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scal, tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(scalars, z, eps_u, eps_c)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def ddim_step_rows(scalars, z, eps_u, eps_c, block_r: int,
                   interpret: bool = True):
    """Per-row-scalar variant: z/eps_u/eps_c (B, R, C) with
    R % block_r == 0 and C % BLOCK_C == 0; scalars (B, 8) f32, one
    [guidance, a_t, s_t, a_n, s_n, clip_x0, 0, 0] row per batch element.
    Same kernel body as :func:`ddim_step_2d` — the batch grid axis selects
    both the latent tile and its scalar row."""
    B, R, C = z.shape
    grid = (B, R // block_r, C // BLOCK_C)
    tile = pl.BlockSpec((1, block_r, BLOCK_C), lambda b, i, j: (b, i, j))
    scal = pl.BlockSpec((1, 8), lambda b, i, j: (b, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scal, tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(scalars, z, eps_u, eps_c)
