from repro.kernels.ddim_step.ops import fused_cfg_ddim_step
