"""Fused CFG + DPM-Solver++(2M) update Pallas kernel.

Per sampler step the 2M solver computes (eps-parameterisation, data
prediction internally; Lu et al., 2022):

    eps     = eps_u + w (eps_c - eps_u)
    x0      = clip((z - sigma_t eps)      / alpha_t)
    x0_prev = clip((z - sigma_t eps_prev) / alpha_t)
    r       = (lambda_t - lambda_prev) / h,   h = lambda_next - lambda_t
    D       = x0 + (x0 - x0_prev) / (2 r)          # lambda-space extrapolation
    z'      = (sigma_next / sigma_t) z - alpha_next expm1(-h) D

Unfused that is the CFG combine plus two data predictions plus the history
blend — 4+ elementwise passes over 4 latent-sized tensors (z, eps_u, eps_c,
eps_prev) with combined-eps / x0 HBM round trips between them.  The kernel
computes z' AND the combined eps (next step's history carry) in one pass:
read 4 tiles, write 2.

The first-step / history-warmup edge case (branch fork restarts history too)
is handled in-kernel by a ``first`` flag scalar: the extrapolation term is
multiplied by ``1 - first``, which reproduces the reference's
``eps_prev := eps`` aliasing exactly (the term is identically zero) without
a separate warm-up launch.  All per-step scalars — guidance, the four
schedule gathers, clip, the three lambdas, the flag — ride in one (1, 16)
block mapped to every grid point.

Two launch shapes share the same kernel body (see ddim_step.py for the
rationale): :func:`dpmpp_step_2d` broadcasts ONE scalar row to the whole
batch; :func:`dpmpp_step_rows` indexes a (B, 16) scalar block by the
batch grid axis so every row carries its own schedule gathers, lambdas
AND warm-up flag — in a packed serving super-batch, one group can sit at
its branch fork (history warm-up) while another is mid-phase.

VMEM budget: 6 tiles x block(256, 256) x 4B = 1.5 MB  << 16 MB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 256

# scalar block layout (1, SCAL_WIDTH) f32 — ops.py packs in this order:
#   [guidance, a_t, s_t, a_n, s_n, clip_x0, lam, lam_prev, lam_next, first,
#    0-padding]
SCAL_WIDTH = 16


def _kernel(scal_ref, z_ref, eu_ref, ec_ref, ep_ref, out_ref, eps_ref):
    w = scal_ref[0, 0]
    a_t, s_t = scal_ref[0, 1], scal_ref[0, 2]
    a_n, s_n = scal_ref[0, 3], scal_ref[0, 4]
    clip = scal_ref[0, 5]
    lam, lam_p, lam_n = scal_ref[0, 6], scal_ref[0, 7], scal_ref[0, 8]
    first = scal_ref[0, 9]

    h = lam_n - lam
    hs = jnp.where(jnp.abs(h) > 1e-8, h, 1e-8)
    r = (lam - lam_p) / hs

    z = z_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    ec = ec_ref[...].astype(jnp.float32)
    ep = ep_ref[...].astype(jnp.float32)

    eps = eu + w * (ec - eu)
    inv_a = 1.0 / jnp.maximum(a_t, 1e-6)
    x0 = (z - s_t * eps) * inv_a
    x0p = (z - s_t * ep) * inv_a
    # static x0-thresholding (matches samplers.dpmpp_2m_step); clip == 0 -> off
    x0 = jnp.where(clip > 0.0, jnp.clip(x0, -clip, clip), x0)
    x0p = jnp.where(clip > 0.0, jnp.clip(x0p, -clip, clip), x0p)
    # first == 1 zeroes the history term — identical to aliasing ep := eps
    d = x0 + (1.0 - first) * (x0 - x0p) / (2.0 * jnp.maximum(r, 1e-8))
    zn = (s_n / jnp.maximum(s_t, 1e-8)) * z - a_n * jnp.expm1(-h) * d
    out_ref[...] = zn.astype(out_ref.dtype)
    eps_ref[...] = eps.astype(eps_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dpmpp_step_2d(scalars, z, eps_u, eps_c, eps_prev, interpret: bool = True):
    """z/eps_u/eps_c/eps_prev (R, C), R % BLOCK_R == 0, C % BLOCK_C == 0;
    scalars (1, SCAL_WIDTH) f32 (layout above).  Returns
    (z_next, eps_combined)."""
    R, C = z.shape
    grid = (R // BLOCK_R, C // BLOCK_C)
    tile = pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j))
    scal = pl.BlockSpec((1, SCAL_WIDTH), lambda i, j: (0, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scal, tile, tile, tile, tile],
        out_specs=(tile, tile),
        out_shape=(jax.ShapeDtypeStruct(z.shape, z.dtype),
                   jax.ShapeDtypeStruct(z.shape, z.dtype)),
        interpret=interpret,
    )(scalars, z, eps_u, eps_c, eps_prev)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def dpmpp_step_rows(scalars, z, eps_u, eps_c, eps_prev, block_r: int,
                    interpret: bool = True):
    """Per-row-scalar variant: tensors (B, R, C) with R % block_r == 0 and
    C % BLOCK_C == 0; scalars (B, SCAL_WIDTH) f32, one row per batch
    element (layout above).  Returns (z_next, eps_combined)."""
    B, R, C = z.shape
    grid = (B, R // block_r, C // BLOCK_C)
    tile = pl.BlockSpec((1, block_r, BLOCK_C), lambda b, i, j: (b, i, j))
    scal = pl.BlockSpec((1, SCAL_WIDTH), lambda b, i, j: (b, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scal, tile, tile, tile, tile],
        out_specs=(tile, tile),
        out_shape=(jax.ShapeDtypeStruct(z.shape, z.dtype),
                   jax.ShapeDtypeStruct(z.shape, z.dtype)),
        interpret=interpret,
    )(scalars, z, eps_u, eps_c, eps_prev)
