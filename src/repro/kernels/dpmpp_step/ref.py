"""Pure-jnp oracle for the fused CFG+DPM-Solver++(2M) kernel.

Mirrors ``guidance.cfg_combine`` + ``samplers.dpmpp_2m_step`` exactly, but
from the per-step scalars the kernel receives (``samplers.dpmpp_scalars``)
rather than the full schedule.  Step scalars (including ``is_first``) may
be plain scalars or (B,) per-row vectors (the packed serving path) —
vectors broadcast along the batch axis via ``bcast_rows``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels._tiles import bcast_rows


def fused_cfg_dpmpp_step_ref(z, eps_u, eps_c, eps_prev, guidance,
                             a_t, s_t, a_n, s_n, lam, lam_p, lam_n,
                             is_first, clip_x0: float = 0.0):
    """Returns (z_next, eps_combined); eps_combined is the history carry."""
    a_t, s_t, a_n, s_n, lam, lam_p, lam_n, is_first = (
        bcast_rows(v, z.ndim)
        for v in (a_t, s_t, a_n, s_n, lam, lam_p, lam_n, is_first))
    zf = z.astype(jnp.float32)
    eps = (eps_u.astype(jnp.float32)
           + guidance * (eps_c.astype(jnp.float32)
                         - eps_u.astype(jnp.float32)))
    ep = jnp.where(jnp.asarray(is_first, jnp.bool_), eps,
                   eps_prev.astype(jnp.float32))
    h = lam_n - lam
    hs = jnp.where(jnp.abs(h) > 1e-8, h, 1e-8)
    r = (lam - lam_p) / hs

    def pred_x0(e):
        x0 = (zf - s_t * e) / jnp.maximum(a_t, 1e-6)
        return jnp.clip(x0, -clip_x0, clip_x0) if clip_x0 else x0

    x0 = pred_x0(eps)
    x0p = pred_x0(ep)
    d = x0 + (x0 - x0p) / (2.0 * jnp.maximum(r, 1e-8))
    zn = (s_n / jnp.maximum(s_t, 1e-8)) * zf - a_n * jnp.expm1(-h) * d
    return zn.astype(z.dtype), eps.astype(z.dtype)
