"""Public wrapper: arbitrary latent shapes -> padded 2-D tiles -> kernel."""
from __future__ import annotations

from repro.kernels._tiles import scalar_block, tile_2d
from repro.kernels.dpmpp_step.dpmpp_step import (BLOCK_C, BLOCK_R,
                                                 SCAL_WIDTH, dpmpp_step_2d)


def fused_cfg_dpmpp_step(z, eps_u, eps_c, eps_prev, guidance,
                         a_t, s_t, a_n, s_n, lam, lam_p, lam_n,
                         is_first, clip_x0: float = 0.0,
                         interpret: bool | None = None):
    """Fused CFG + DPM-Solver++(2M) update for latents of any shape (B, ...).

    Returns ``(z_next, eps_combined)`` — the combined eps feeds the solver's
    history carry, so the CFG combine never takes a separate HBM pass.  All
    step scalars (guidance, the four schedule gathers, the three lambdas
    from ``samplers.dpmpp_scalars``, clip_x0, the ``is_first`` warm-up flag)
    may be python floats or traced jnp scalars — e.g. gathered per scan
    step — and ride to the kernel in one (1, 16) block.  ``is_first`` may be
    a traced bool; it is carried as a 0/1 float and zeroes the history
    extrapolation term in-kernel (exactly the reference's ``eps_prev := eps``
    aliasing).  ``interpret=None`` resolves via dispatch (env override, else
    compiled only on TPU).
    """
    assert z.shape == eps_u.shape == eps_c.shape == eps_prev.shape
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    tiles, untile = tile_2d(BLOCK_R, BLOCK_C, z, eps_u, eps_c, eps_prev)
    # layout must match the kernel's scal_ref reads (see dpmpp_step.py)
    scal = scalar_block((guidance, a_t, s_t, a_n, s_n, clip_x0,
                         lam, lam_p, lam_n, is_first), SCAL_WIDTH)
    zn, eps = dpmpp_step_2d(scal, *tiles, interpret=interpret)
    return untile(zn), untile(eps)
