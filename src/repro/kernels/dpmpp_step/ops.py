"""Public wrapper: arbitrary latent shapes -> padded tiles -> kernel.

Scalars with a batch axis ((B,) vectors) select the per-row kernel launch
— same body, per-row scalar block; see ddim_step/ops.py.  Mixed-sampler
packs invoke this on the statically-gathered dpmpp rows only (scattered
back afterwards); a full-stack compute + select would not be bitwise-safe
against the per-group oracle — see the note in ddim_step/ops.py."""
from __future__ import annotations

from repro.kernels._tiles import (per_row_scalars, row_block, scalar_block,
                                  scalar_rows, tile_2d, tile_rows)
from repro.kernels.dpmpp_step.dpmpp_step import (BLOCK_C, BLOCK_R,
                                                 SCAL_WIDTH, dpmpp_step_2d,
                                                 dpmpp_step_rows)


def fused_cfg_dpmpp_step(z, eps_u, eps_c, eps_prev, guidance,
                         a_t, s_t, a_n, s_n, lam, lam_p, lam_n,
                         is_first, clip_x0: float = 0.0,
                         interpret: bool | None = None):
    """Fused CFG + DPM-Solver++(2M) update for latents of any shape (B, ...).

    Returns ``(z_next, eps_combined)`` — the combined eps feeds the solver's
    history carry, so the CFG combine never takes a separate HBM pass.  All
    step scalars (guidance, the four schedule gathers, the three lambdas
    from ``samplers.dpmpp_scalars``, clip_x0, the ``is_first`` warm-up flag)
    may be python floats or traced jnp scalars — e.g. gathered per scan
    step — and ride to the kernel in one (1, 16) block; any of them may
    instead be a (B,) vector (rows at different grid positions, the packed
    serving path), which launches the per-row variant with a (B, 16)
    scalar block.  ``is_first`` may be a traced bool (or per-row bool
    vector); it is carried as a 0/1 float and zeroes the history
    extrapolation term in-kernel (exactly the reference's ``eps_prev := eps``
    aliasing).  ``interpret=None`` resolves via dispatch (env override, else
    compiled only on TPU).
    """
    assert z.shape == eps_u.shape == eps_c.shape == eps_prev.shape
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    # layout must match the kernel's scal_ref reads (see dpmpp_step.py)
    values = (guidance, a_t, s_t, a_n, s_n, clip_x0,
              lam, lam_p, lam_n, is_first)
    if per_row_scalars(*values):
        br = row_block(z[0].size, BLOCK_C, BLOCK_R)
        tiles, untile = tile_rows(br, BLOCK_C, z, eps_u, eps_c, eps_prev)
        scal = scalar_rows(values, SCAL_WIDTH, z.shape[0])
        zn, eps = dpmpp_step_rows(scal, *tiles, block_r=br,
                                  interpret=interpret)
        return untile(zn), untile(eps)
    tiles, untile = tile_2d(BLOCK_R, BLOCK_C, z, eps_u, eps_c, eps_prev)
    scal = scalar_block(values, SCAL_WIDTH)
    zn, eps = dpmpp_step_2d(scal, *tiles, interpret=interpret)
    return untile(zn), untile(eps)
