from repro.kernels.dpmpp_step.ops import fused_cfg_dpmpp_step  # noqa: F401
