"""Oracle for the masked group mean (same math as core.shared_sampling)."""
from __future__ import annotations

import jax.numpy as jnp


def masked_group_mean_ref(x, mask):
    """x (K, N, ...); mask (K, N) -> (K, ...)."""
    m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
    return (jnp.sum(x.astype(jnp.float32) * m, axis=1)
            / jnp.maximum(jnp.sum(m, axis=1), 1e-6)).astype(x.dtype)
