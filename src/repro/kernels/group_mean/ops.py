"""Public wrapper: (K, N, ...) feature pytrees -> padded (K,N,F) kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.group_mean.group_mean import BLOCK_F, group_mean_knf


def masked_group_mean(x, mask, interpret: bool | None = None):
    """x (K, N, ...); mask (K, N) f32 -> masked mean over N: (K, ...).

    ``interpret=None`` resolves via dispatch (env override, else compiled
    only on TPU)."""
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    K, N = x.shape[:2]
    feat_shape = x.shape[2:]
    F = 1
    for d in feat_shape:
        F *= d
    pad = (-F) % BLOCK_F
    x2 = x.reshape(K, N, F)
    if pad:
        x2 = jnp.pad(x2, ((0, 0), (0, 0), (0, pad)))
    out = group_mean_knf(x2, mask.reshape(K, N, 1).astype(jnp.float32),
                         interpret=interpret)
    return out.reshape(K, -1)[:, :F].reshape((K,) + feat_shape)
