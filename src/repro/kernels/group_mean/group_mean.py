"""Masked group-mean Pallas kernel.

Computes c̄_k = sum_n mask[k,n] x[k,n,:] / sum_n mask[k,n] — the shared
condition / shared latent of Alg. 1/2.  One grid step per (group, feature
block): the member axis N stays resident in VMEM (N <= 8 by construction,
paper groups are 2-5 members), so the reduction is a single pass.

Block: (1, N, BLOCK_F) x f32 = 8 * 512 * 4B = 16 KB  << VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_F = 512


def _kernel(x_ref, m_ref, out_ref):
    x = x_ref[0].astype(jnp.float32)            # (N, F)
    m = m_ref[0].astype(jnp.float32)            # (N, 1)  broadcast-ready
    s = jnp.sum(x * m, axis=0, keepdims=True)   # (1, F)
    cnt = jnp.maximum(jnp.sum(m), 1e-6)
    out_ref[0] = (s / cnt).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def group_mean_knf(x, mask, interpret: bool = True):
    """x (K, N, F) with F % BLOCK_F == 0; mask (K, N, 1) -> (K, 1, F)."""
    K, N, F = x.shape
    grid = (K, F // BLOCK_F)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, N, BLOCK_F), lambda k, f: (k, 0, f)),
                  pl.BlockSpec((1, N, 1), lambda k, f: (k, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, BLOCK_F), lambda k, f: (k, 0, f)),
        out_shape=jax.ShapeDtypeStruct((K, 1, F), x.dtype),
        interpret=interpret,
    )(x, mask)
