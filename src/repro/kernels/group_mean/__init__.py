from repro.kernels.group_mean.ops import masked_group_mean
