"""Public wrapper: (B, S, H, D) GQA layout -> padded (BH, S, Dp) kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    BLOCK_K, BLOCK_Q, flash_attention_bhsd)


def flash_attention(q, k, v, *, causal: bool = True, interpret: bool = True):
    """q (B, Sq, H, D); k/v (B, Sk, Hkv, D), H % Hkv == 0 -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(D)
    g = H // Hkv
    if g > 1:                       # materialise GQA repeat for the kernel
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)

    def to_bhsd(x, S):
        x = x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        pad_s = (-S) % (BLOCK_Q if S == Sq else BLOCK_K)
        pad_d = (-D) % 128
        return jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_d))), pad_s

    qp, _ = to_bhsd(q, Sq)
    kp, _ = to_bhsd(k, Sk)
    vp, _ = to_bhsd(v, Sk)
    # zero-padded key rows are masked inside the kernel via seq_k
    out = flash_attention_bhsd(qp, kp, vp, causal=causal, scale=scale,
                               interpret=interpret, seq_k=Sk)
    out = out[:, :Sq, :D].reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return out
