"""Public wrapper: (B, S, H, D) GQA layout -> padded (BH, S, Dp) kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    BLOCK_K, BLOCK_Q, flash_attention_bhsd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    interpret: bool | None = None):
    """q (B, Sq, H, D); k/v (B, Sk, Hkv, D), H % Hkv == 0 -> (B, Sq, H, D).

    The GQA group is folded into the *batch* axis head-major
    (B, Hkv, g) so the kernel's ``b // g`` index map shares each K/V
    block across its g query heads — no ``jnp.repeat`` materialisation.
    ``window > 0`` (causal only) runs the sliding-window variant: the K/V
    index map is offset to the causal frontier and the K grid dimension
    shrinks to the blocks a query block's window can touch.  head_dim in
    (128, 256] runs the two-lane-tile D variant (padded to 256 lanes);
    D > 256 has no kernel — use attn_impl='chunked'.
    ``interpret=None`` resolves via :func:`repro.kernels.dispatch.
    resolve_interpret` (env override, else compiled only on TPU).
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if D > 256:
        raise ValueError(
            f"flash_attention supports head_dim <= 256 (two lane tiles), "
            f"got D={D}; split heads or use attn_impl='chunked'")
    if window and not causal:
        raise ValueError("sliding window requires causal attention; "
                         "use attn_impl='chunked' for non-causal windows")
    if H % Hkv:
        raise ValueError(f"n_heads {H} not divisible by n_kv_heads {Hkv}")
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    g = H // Hkv

    def to_bhsd(x, *, kv: bool):
        # block choice is keyed on tensor ROLE (q pads to BLOCK_Q, k/v to
        # BLOCK_K) — keying on S == Sq misclassifies K/V whenever Sq == Sk.
        Bx, S, Hx, _ = x.shape
        x = x.transpose(0, 2, 1, 3).reshape(Bx * Hx, S, D)
        pad_s = (-S) % (BLOCK_K if kv else BLOCK_Q)
        pad_d = (-D) % 128
        return jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_d)))

    qp = to_bhsd(q, kv=False)                     # (B*H, Sq_p, Dp)
    kp = to_bhsd(k, kv=True)                      # (B*Hkv, Sk_p, Dp)
    vp = to_bhsd(v, kv=True)
    # zero-padded key rows are masked inside the kernel via seq_k
    out = flash_attention_bhsd(qp, kp, vp, causal=causal, scale=scale,
                               interpret=interpret, seq_k=Sk, q_per_kv=g,
                               window=window)
    out = out[:, :Sq, :D].reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return out
