"""Blocked online-softmax attention (FlashAttention-style) for TPU.

The DiT denoiser evaluates full self-attention over 1024-4096 latent tokens
every sampler step — the single hottest matmul loop in SAGE sampling — and
the transformer substrate uses the same pattern.  TPU adaptation (not a CUDA
port): tiles are MXU-aligned (128 x head_dim), the K/V loop is the innermost
*grid* dimension so K/V blocks stream HBM -> VMEM while running max /
denominator accumulators live in VMEM scratch across grid steps (TPU grids
execute sequentially per core — the standard Pallas-TPU reduction idiom —
rather than CUDA's one-CTA-per-tile + atomics).

Sliding-window attention rides on the K *index map*, not a materialised
mask: with window W only ``nkw = ceil-ish((W + BQ) / BK)`` K blocks can
intersect a query block's visible span, so the grid's K dimension shrinks
from ``Sk/BK`` to ``nkw`` and the index map pins the visited blocks to the
causal frontier (``start(i) = clip(last_causal_block(i) - nkw + 1, 0,
nk - nkw)`` — the upper clamp keeps cross-attention shapes with Sq > Sk
in range).
Blocks pulled in left of the window and right of the diagonal are killed by
the in-kernel window/causal masks; block-granularity work drops from
O(Sq Sk) to O(Sq W).

Shapes: q (B, H, S, D), kv (B, H, Skv, D); D <= 256 padded to lane width —
head_dim in (128, 256] runs as a two-lane-tile D block (scores contract
over both 128-lane tiles, acc scratch widens to (BQ, 256)).
VMEM: q/k/v/o blocks + (BQ, BK) scores ~ 128*256*4B * 5 ~ 0.7 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _k_start(qi, *, block_q: int, block_k: int, nkw: int, nk: int):
    """First K block visited for query block qi — the window of nkw visited
    blocks ends at the last block a causal query row can see, clamped into
    the valid block range (cross-attention may have Sq > Sk, where the
    causal frontier runs past the last K block; with nkw == nk this
    degenerates to 0).  Shared by the BlockSpec index map and the in-kernel
    column reconstruction."""
    last = (qi * block_q + block_q - 1) // block_k
    return jnp.clip(last - (nkw - 1), 0, nk - nkw)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, seq_k: int, nkw: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                    # (BK, D)
    v = v_ref[0].astype(jnp.float32)                    # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # actual K block this grid step visits — mirrors the K/V index map
    kb = _k_start(qi, block_q=block_q, block_k=block_k, nkw=nkw,
                  nk=nk) + ki
    cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = cols < seq_k                     # mask zero-padded key rows
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    if causal:
        valid &= cols <= rows
    if window:
        # kills blocks the index map pulls in left of the sliding window
        valid &= cols > rows - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                 # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                              # (BQ, BK)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc

    @pl.when(ki == nkw - 1)
    def _fin():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "interpret", "seq_k",
                                    "q_per_kv", "window"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         scale: float = 1.0, interpret: bool = True,
                         seq_k: int = 0, q_per_kv: int = 1,
                         window: int = 0):
    """q (BH, Sq, D), k/v (BH // q_per_kv, Sk, D) -> (BH, Sq, D).
    Sq % BLOCK_Q == 0, Sk % BLOCK_K == 0, D in {128, 256} (pad lanes
    upstream).  seq_k = true (pre-padding) key length for masking; 0 -> Sk.

    GQA rides on the batch index map: query batch b reads K/V batch
    b // q_per_kv, so the group is never materialised in HBM — q must be
    laid out head-major (..., Hkv, g) along its batch axis.

    window > 0 (causal only) trims the K grid dimension to the nkw blocks
    that can intersect a query block's window and offsets the K/V index map
    to the causal frontier — out-of-window work is never fetched, not just
    masked.  window == 0 visits every K block (nkw == Sk/BK) and the index
    map degenerates to the identity."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    assert BH == k.shape[0] * q_per_kv, (BH, k.shape[0], q_per_kv)
    assert D in (128, 256), D
    assert window == 0 or causal, "sliding window requires causal"
    nk = Sk // BLOCK_K
    if window:
        # max K blocks a (BQ-row, W-wide) causal band can intersect
        nkw = min(nk, (window + BLOCK_Q - 2) // BLOCK_K + 2)
    else:
        nkw = nk
    grid = (BH, Sq // BLOCK_Q, nkw)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, block_q=BLOCK_Q,
                               block_k=BLOCK_K, seq_k=seq_k or Sk, nkw=nkw,
                               nk=nk)
    g = q_per_kv
    start = functools.partial(_k_start, block_q=BLOCK_Q, block_k=BLOCK_K,
                              nkw=nkw, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_K, D),
                         lambda b, i, j: (b // g, start(i) + j, 0)),
            pl.BlockSpec((1, BLOCK_K, D),
                         lambda b, i, j: (b // g, start(i) + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
