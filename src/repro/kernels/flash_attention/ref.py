"""Oracle: plain softmax attention in fp32."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float = 1.0):
    """q (BH, Sq, D), k/v (BH, Sk, D)."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
