"""Oracle: the pure-jnp SSD (models/ssm.ssd_chunked is the production twin)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dA, B_, C_, chunk):
    """x (b,l,h,p); dA (b,l,h); B_/C_ (b,l,n) -> (y, final_state)."""
    return ssd_chunked(x, dA, B_, C_, chunk)
