"""Full SSD assembled around the Pallas intra-chunk kernel: kernel computes
Y_diag + per-chunk states; the (cheap, sequential) inter-chunk recurrence
and off-diagonal correction stay in jnp."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_intra_chunk


def ssd_chunked_kernel(x, dA, B_, C_, chunk: int,
                       interpret: bool | None = None):
    """Same contract as models.ssm.ssd_chunked (g=1 groups):
    x (b,l,h,p) pre-multiplied by dt; dA (b,l,h); B_/C_ (b,l,n).
    ``interpret=None`` resolves via dispatch (compiled only on TPU)."""
    if interpret is None:
        from repro.kernels.dispatch import resolve_interpret
        interpret = resolve_interpret()
    b, l, h, p = x.shape
    n = B_.shape[-1]
    assert l % chunk == 0, (l, chunk)
    c, Q = l // chunk, chunk

    xf = x.astype(jnp.float32).reshape(b, c, Q, h, p)
    dAc = dA.astype(jnp.float32).reshape(b, c, Q, h)
    Bf = B_.astype(jnp.float32).reshape(b, c, Q, n)
    Cf = C_.astype(jnp.float32).reshape(b, c, Q, n)

    # flatten (b, c, h) -> grid; broadcast B/C over heads
    xg = xf.transpose(0, 1, 3, 2, 4).reshape(b * c * h, Q, p)
    dg = dAc.transpose(0, 1, 3, 2).reshape(b * c * h, Q)
    Bg = jnp.broadcast_to(Bf[:, :, None], (b, c, h, Q, n)).reshape(
        b * c * h, Q, n)
    Cg = jnp.broadcast_to(Cf[:, :, None], (b, c, h, Q, n)).reshape(
        b * c * h, Q, n)

    y_diag, states = ssd_intra_chunk(dg, xg, Bg, Cg, interpret=interpret)
    y_diag = y_diag.reshape(b, c, h, Q, p).transpose(0, 1, 3, 2, 4)
    states = states.reshape(b, c, h, p, n)

    # inter-chunk recurrence (jnp: O(c) sequential, bandwidth-trivial)
    cum = jnp.cumsum(dAc, axis=2)                       # (b,c,Q,h)
    chunk_decay = jnp.exp(cum[:, :, -1]).transpose(0, 2, 1)  # (b,h,c)

    def step(s, inp):
        st, dec = inp
        return s * dec[..., None, None] + st, s

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 0, 2, 3, 4)                # (b,c,h,p,n)

    out_decay = jnp.exp(cum)                            # (b,c,Q,h)
    y_off = jnp.einsum("bzqn,bzhpn,bzqh->bzqhp", Cf, prev, out_decay)
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(x.dtype), final
