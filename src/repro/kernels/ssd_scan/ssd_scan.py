"""Mamba2 SSD intra-chunk Pallas kernel.

TPU adaptation of the SSD "state-space duality" chunk computation
[arXiv:2405.21060]: one grid cell = one (batch*head, chunk) tile held
entirely in VMEM —

    L      = exp(segsum(dA))        (Q, Q)  causal decay mask
    Y_diag = ((C B^T) ∘ L) x        (Q, P)  MXU matmuls
    state  = (x * decay)^T B        (P, N)  chunk's contribution to the
                                            inter-chunk recurrence

Q = N = 128 keeps every matmul MXU-shaped; the O(Q^2) decay matrix lives
in VMEM (64 KB fp32) and never touches HBM — that is the point of the
kernel (the jnp path materialises it per chunk).  The sequential
inter-chunk recurrence (c ~ 32-4096 steps) stays a lax.scan outside: it is
O(c·P·N) — bandwidth-trivial — and TPU grids execute sequentially anyway.

VMEM/grid cell: x,y (Q,P) + B,C (Q,N) + L,S (Q,Q) fp32 ≈ 0.4 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(dA_ref, x_ref, b_ref, c_ref, y_ref, state_ref):
    dA = dA_ref[0].astype(jnp.float32)                    # (Q,)
    x = x_ref[0].astype(jnp.float32)                      # (Q, P)
    B = b_ref[0].astype(jnp.float32)                      # (Q, N)
    C = c_ref[0].astype(jnp.float32)                      # (Q, N)
    Q = dA.shape[0]

    cum = jnp.cumsum(dA)                                  # (Q,)
    seg = cum[:, None] - cum[None, :]                     # (Q, Q)
    tril = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.where(tril, jnp.exp(seg), 0.0)

    S = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * L
    y_ref[0] = jax.lax.dot(S, x,
                           preferred_element_type=jnp.float32
                           ).astype(y_ref.dtype)

    decay = jnp.exp(cum[-1] - cum)                        # (Q,)
    xw = x * decay[:, None]
    state_ref[0] = jax.lax.dot_general(
        xw, B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(dA, x, B, C, interpret: bool = True):
    """dA (G, Q); x (G, Q, P); B/C (G, Q, N) with G = batch*heads*chunks
    -> (y_diag (G, Q, P), chunk_states (G, P, N))."""
    G, Q, P = x.shape
    N = B.shape[-1]
    grid = (G,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q), lambda g: (g, 0)),
            pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, P, N), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((G, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(dA, x, B, C)
