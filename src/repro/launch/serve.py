"""Serving launcher: batched decode loop for any assigned architecture
(prefill -> N decode steps with the KV/state cache), reporting tokens/s and
cache bytes — plus the SAGE shared-prefix mode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
        --batch 4 --prompt-len 64 --gen 32 [--shared-prefix]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import transformer as tfm
from repro.serving.kvcache import cache_bytes, fork_model_cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--shared-prefix", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    max_len = args.prompt_len + args.gen + 8

    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.zeros(
            (args.batch, cfg.n_image_tokens, cfg.vision_dim))
    if cfg.family == "encdec":
        extras["frames"] = jnp.zeros((args.batch, 32, cfg.enc_input_dim))

    decode = jax.jit(lambda c, t, p: tfm.decode_step(params, cfg, c, t, p))

    t0 = time.time()
    if args.shared_prefix:       # SAGE analogue: one trunk, fork, decode
        prompt = rng.randint(0, cfg.vocab, (1, args.prompt_len))
        ex1 = {k: v[:1] for k, v in extras.items()}
        logits, trunk = tfm.prefill(params, cfg, jnp.asarray(prompt),
                                    extras=ex1, max_len=max_len)
        cache = fork_model_cache(trunk, args.batch)
        steps_cost = args.prompt_len + args.batch * args.gen
    else:
        prompts = rng.randint(0, cfg.vocab, (args.batch, args.prompt_len))
        logits, cache = tfm.prefill(params, cfg, jnp.asarray(prompts),
                                    extras=extras, max_len=max_len)
        steps_cost = args.batch * (args.prompt_len + args.gen)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    if tok.shape[0] == 1 and args.batch > 1:
        tok = jnp.repeat(tok, args.batch, 0)
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} shared_prefix={args.shared_prefix}")
    print(f"prefill {t_prefill:.2f}s | decode {t_decode:.2f}s "
          f"({args.batch*args.gen/max(t_decode,1e-9):.1f} tok/s) | "
          f"cache {cache_bytes(cache)/2**20:.1f} MiB | "
          f"token-steps {steps_cost}")


if __name__ == "__main__":
    main()
