"""Production mesh construction.

Functions (not module-level constants) so importing never touches jax
device state.  Target: TPU v5e, 16x16 = 256 chips/pod, 2 pods = 512.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
