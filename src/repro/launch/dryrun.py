import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, print memory/cost analysis, extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

One JSON per case lands in experiments/dryrun/ (safe for parallel runs).
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro.config import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_case

# TPU v5e roofline constants live in the import-safe repro.launch.costs
# (importing *this* module mutates XLA_FLAGS; reports must not pay that)
from repro.launch.costs import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:\w+\[[\d,]*\][^)=]*?)+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str):
    """Sum per-device output bytes of every cross-device collective, by kind."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _measure(arch, shape_name, mesh, smoke, kw):
    """lower+compile one build; returns (flops, bytes, coll, compiled, dt)."""
    case = build_case(arch, shape_name, mesh, smoke=smoke, **kw)
    donate = case.static.get("donate", ())
    t0 = time.time()
    with mesh:
        lowered = jax.jit(case.fn, donate_argnums=donate).lower(*case.args)
        compiled = lowered.compile()
    dt = time.time() - t0
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = dict(cost or {})
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return flops, bytes_acc, coll, compiled, case, dt


def _n_blocks_full(cfg) -> int:
    per = len(cfg.pattern) if cfg.pattern else 1
    prefix = cfg.moe.first_moe_layer if cfg.family == "moe" else 0
    return (cfg.n_layers - prefix - len(cfg.remainder)) // per


# §Perf hillclimb variants: name -> builder kwargs
VARIANTS = {
    "chunked": {"attn_impl": "chunked"},          # online-softmax attention
    "chunked4k": {"attn_impl": "chunked", "attn_block": 4096},
    "chunked8k": {"attn_impl": "chunked", "attn_block": 8192},
    "chunked512": {"attn_impl": "chunked", "attn_block": 512},
    "dp_only": {"no_tp": True},                   # replicate params (sage)
    "seqshard": {"cache_seq_shard": True},        # KV cache seq over model
    "chunked_seqshard": {"attn_impl": "chunked", "cache_seq_shard": True},
    "adafactor": {"optim": "adafactor"},          # factored opt state
    "noremat": {"remat": False},
    "chunked_noremat": {"attn_impl": "chunked", "remat": False},
}


def run_case(arch: str, shape_name: str, multi_pod: bool, smoke: bool = False,
             outdir: str = "experiments/dryrun", variant: str = "",
             builder_kw=None, fast: bool = False):
    """Roofline measurement per case:

    1. FULL config, scan-lowered -> proves .lower().compile() succeeds on
       the production mesh and yields memory_analysis (real buffer sizes).
    2. Two small UNROLLED variants (k1/k2 scanned blocks) -> per-block
       flops/bytes/collectives by exact linear extrapolation; HLO cost
       analysis counts while-loop bodies once, so scanned full configs
       undercount ~n_layers x, while full unrolls compile too slowly.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    kw = dict(VARIANTS.get(variant, {}))
    kw.update(builder_kw or {})
    cfg = get_config(arch, smoke=smoke)
    nb_full = _n_blocks_full(cfg)

    # --- 1. full config, scan lowering ---------------------------------
    f_full, b_full, c_full, compiled, case, t_full = _measure(
        arch, shape_name, mesh, smoke, {**kw, "unroll": False})
    mem = compiled.memory_analysis()
    t_compile = t_full

    # --- 2. extrapolation pair ------------------------------------------
    k1, k2 = (2, 5) if nb_full >= 5 else (1, max(2, nb_full))
    if fast:      # multi-pod pass: compile proof only (roofline is 16x16)
        flops, bytes_acc, coll = f_full, b_full, c_full
    elif k2 > k1:
        f1, b1, c1, _, _, t1 = _measure(arch, shape_name, mesh, smoke,
                                        {**kw, "unroll": True,
                                         "n_blocks": k1})
        f2, b2, c2, _, _, t2 = _measure(arch, shape_name, mesh, smoke,
                                        {**kw, "unroll": True,
                                         "n_blocks": k2})
        t_compile += t1 + t2

        def extrap(v1, v2):
            body = (v2 - v1) / (k2 - k1)
            return max(v1 - k1 * body, 0.0) + nb_full * body

        flops = extrap(f1, f2)
        bytes_acc = extrap(b1, b2)
        coll = {k: extrap(c1.get(k, 0), c2.get(k, 0))
                for k in set(c1) | set(c2)}
    else:
        flops, bytes_acc, coll = f_full, b_full, c_full

    if shape_name == "sage_serve":
        K, N = case.static["batch"], case.static["seq"]
        n_lat = (cfg.latent_size // cfg.patch) ** 2
        token_passes = 2 * (K + K * N) * n_lat          # CFG doubles evals
        model_flops = 2.0 * cfg.n_params() * token_passes
    else:
        tokens = SHAPES[shape_name].global_batch * (
            SHAPES[shape_name].seq_len
            if SHAPES[shape_name].kind != "decode" else 1)
        model_flops = 6.0 * cfg.n_active_params() * tokens
        if SHAPES[shape_name].kind == "train":
            model_flops *= 3.0  # fwd + bwd

    # cost_analysis runs on the post-SPMD (per-device) module
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips), "variant": variant or "baseline",
        "compile_s": round(t_compile, 2),
        "full_scan_compile_s": round(t_full, 2),
        "flops_per_dev": flops, "bytes_per_dev": bytes_acc,
        "collective_bytes_per_dev": coll,
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s": bytes_acc / HBM_BW,
        "collective_term_s": coll["total"] / ICI_BW,
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / (flops * n_chips)
                               if flops else 0.0),
        "memory_analysis": {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if mem is not None and hasattr(mem, k)},
        "static": case.static,
    }
    terms = {"compute": res["compute_term_s"], "memory": res["memory_term_s"],
             "collective": res["collective_term_s"]}
    res["bottleneck"] = max(terms, key=terms.get)

    pathlib.Path(outdir).mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape_name}_{res['mesh']}"
    if variant:
        tag += f"_{variant}"
    with open(f"{outdir}/{tag}.json", "w") as f:
        json.dump(res, f, indent=1)
    print(f"[dryrun] {tag}: compile={t_compile:.1f}s "
          f"flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
          f"coll/dev={coll['total']:.3e} bottleneck={res['bottleneck']}")
    print(f"  memory_analysis: {res['memory_analysis']}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + ["sage_serve", None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="")
    ap.add_argument("--fast", action="store_true",
                    help="full-config compile proof only (no roofline "
                         "extrapolation) — used for the multi-pod pass")
    args = ap.parse_args()

    from repro.configs import ASSIGNED
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}"
            out = pathlib.Path(args.out) / (
                f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
                + (f"_{args.variant}" if args.variant else "") + ".json")
            if args.all and out.exists():
                print(f"[dryrun] skip existing {out}")
                continue
            try:
                run_case(arch, shape, args.multi_pod, smoke=args.smoke,
                         outdir=args.out, variant=args.variant,
                         fast=args.fast)
            except Exception as e:  # noqa: BLE001
                failures.append((tag, repr(e)))
                print(f"[dryrun] FAIL {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("[dryrun] all cases OK")


if __name__ == "__main__":
    main()
