"""Roofline report CLI: renders experiments/dryrun/*.json as markdown.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16]
    PYTHONPATH=src python -m repro.launch.roofline --variants  # §Perf view
"""
from __future__ import annotations

import argparse
import glob
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--variants", action="store_true",
                    help="show §Perf variants next to their baselines")
    args = ap.parse_args()

    rows = [json.load(open(f)) for f in sorted(glob.glob(f"{args.dir}/*.json"))]
    if args.variants:
        keys = {(r["arch"], r["shape"]) for r in rows
                if r.get("variant", "baseline") != "baseline"}
        print("| arch | shape | variant | compute s | memory s | "
              "collective s | bottleneck |")
        print("|---|---|---|---|---|---|---|")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                             r.get("variant", ""))):
            if (r["arch"], r["shape"]) not in keys or r["mesh"] != args.mesh:
                continue
            print(f"| {r['arch']} | {r['shape']} | "
                  f"{r.get('variant','baseline')} "
                  f"| {r['compute_term_s']:.2e} | {r['memory_term_s']:.2e} "
                  f"| {r['collective_term_s']:.2e} | {r['bottleneck']} |")
        return
    from benchmarks.roofline_report import markdown_table
    print(markdown_table(rows, mesh=args.mesh))


if __name__ == "__main__":
    main()
