"""Import-safe roofline cost model (TPU v5e hardware constants).

``launch/dryrun.py`` owns the *measured* roofline (lower + compile every
(arch x shape) on the production mesh and read XLA's cost analysis), but
importing it has a deliberate side effect: it forces
``--xla_force_host_platform_device_count=512`` into ``XLA_FLAGS`` before
JAX initialises, which is exactly wrong for anything that is not a
dry-run.  This module holds the shared hardware constants and the small
closed-form predictors that the serving telemetry reports
(``serving/reports.py``) need, with no JAX import and no environment
mutation; ``dryrun.py`` imports the constants back from here so there is
a single source of truth.

The predictors are deliberately first-order: they model the scheduler's
*tick economics* (segments per phase, rows per launch, NFE ledger), not
XLA's fusion choices.  Their job in a capacity report is to make the gap
between "what the tick loop should have cost" and "what the telemetry
says it cost" visible — pad waste, cache savings, retry waste, and
stalls are exactly that gap.
"""
from __future__ import annotations

from dataclasses import dataclass

# ---------------------------------------------------------------------------
# TPU v5e hardware model (roofline constants; chips = mesh size)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (counted once per op byte)


def denoiser_flops_per_eval(n_params: float, n_tokens: int) -> float:
    """FLOPs of ONE denoiser evaluation of one latent row.

    2 FLOPs per param per token (matmul fwd), doubled for CFG's
    unconditional+conditional pair — the same convention as dryrun's
    ``sage_serve`` model-flops term.
    """
    return 2.0 * n_params * 2 * n_tokens


def roofline_seconds(flops: float, bytes_acc: float = 0.0,
                     coll_bytes: float = 0.0, chips: int = 1) -> float:
    """Lower-bound wall seconds: the max of the three roofline terms."""
    c = max(chips, 1)
    return max(flops / c / PEAK_FLOPS, bytes_acc / c / HBM_BW,
               coll_bytes / ICI_BW)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b) if b else 0


@dataclass(frozen=True)
class DrainPrediction:
    """Closed-form tick economics of draining a request set."""
    groups: int
    shared_segments: int      # per group
    branch_segments: int      # per group
    ticks: int                # predicted ticks-to-drain
    nfe: int                  # predicted NFE (no cache, no faults)
    nfe_independent: int      # per-request baseline the saving is vs.


def predict_drain(requests: int, group_size: int, total_steps: int,
                  n_shared: int, slice_steps: int,
                  max_groups_per_tick: int | None = None,
                  ) -> DrainPrediction:
    """Predict ticks-to-drain and NFE for ``requests`` similar requests.

    Assumes full groups of ``group_size`` (the grouping optimum), no
    trunk-cache hits, no faults: one segment per selected group per
    tick, shared phase charging 1 NFE-row per step per group and branch
    charging ``group_size`` rows per step.  Under a
    ``max_groups_per_tick`` cap the in-flight set advances in waves of
    ``cap`` groups.  Observed ticks above this are queueing + holds +
    retries; observed NFE below it is cache savings — the capacity
    report prints both gaps.
    """
    if requests <= 0 or total_steps <= 0:
        return DrainPrediction(0, 0, 0, 0, 0, 0)
    group_size = max(group_size, 1)
    slice_steps = max(slice_steps, 1)
    n_shared = min(max(n_shared, 0), total_steps)
    groups = _ceil_div(requests, group_size)
    shared_segs = _ceil_div(n_shared, slice_steps)
    branch_segs = _ceil_div(total_steps - n_shared, slice_steps)
    per_group_ticks = shared_segs + branch_segs
    if max_groups_per_tick is None or groups <= max_groups_per_tick:
        ticks = per_group_ticks
    else:
        ticks = per_group_ticks * _ceil_div(groups, max_groups_per_tick)
    nfe = groups * n_shared + requests * (total_steps - n_shared)
    return DrainPrediction(groups, shared_segs, branch_segs, ticks, nfe,
                           requests * total_steps)
