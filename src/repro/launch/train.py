"""Training launcher for the transformer substrate.

Runs real steps on the available devices (CPU smoke / debug mesh here; the
same pjit path lowers to the production mesh via launch/dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --smoke --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import OptimConfig, get_config
from repro.data.synthetic import token_stream
from repro.models import transformer as tfm
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    make_optimizer)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optim", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    oc = OptimConfig(kind=args.optim, lr=args.lr)
    opt = make_optimizer(oc)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.zeros(
            (args.batch, cfg.n_image_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "encdec":
        extras["frames"] = jnp.zeros(
            (args.batch, max(args.seq // 4, 16), cfg.enc_input_dim),
            jnp.float32)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: tfm.lm_loss(p, cfg, batch))(params)
        grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params, oc.lr)
        return apply_updates(params, updates), opt_state, loss, gnorm

    stream = token_stream(cfg.vocab, args.batch, args.seq)
    losses, t0 = [], time.time()
    for i in range(args.steps):
        batch = {**next(stream), **extras}
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss, gnorm = train_step(params, opt_state, batch)
        losses.append(float(loss))
        if i % 5 == 0:
            print(f"step {i:4d} loss={losses[-1]:.4f} gnorm={float(gnorm):.2f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print(f"loss {losses[0]:.4f} -> {np.mean(losses[-3:]):.4f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps, params)
        print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()
