"""Dry-run builders: step function + fully-sharded ShapeDtypeStruct inputs
for every (architecture x input-shape) pair on a given mesh.

Everything is AOT: ``jax.eval_shape`` produces the param/opt/cache trees, the
partitioner attaches NamedShardings, and the caller lowers with
``jax.jit(fn).lower(*args)`` — no arrays are ever allocated.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (ModelConfig, OptimConfig, SHAPES, ShapeConfig,
                          get_config)
from repro.models import transformer as tfm
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    make_optimizer)
from repro.sharding import partition

# dense/MoE/VLM/enc-dec archs serve long_500k through a sliding-window cache
# of this size (sub-quadratic requirement; DESIGN.md §4)
SERVE_WINDOW = 4096
# audio frontend downsampling: encoder frames per decoder token ratio
ENC_FRAMES_DIV = 4


class DryrunCase(NamedTuple):
    name: str
    fn: Any
    args: Tuple
    static: Dict[str, Any]


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _extras_specs(cfg: ModelConfig, batch: int, seq: int, mesh, ba):
    if cfg.family == "vlm":
        return {"image_embeds": _sds((batch, cfg.n_image_tokens,
                                      cfg.vision_dim), jnp.bfloat16, mesh,
                                     P(ba, None, None))}
    if cfg.family == "encdec":
        return {"frames": _sds((batch, max(seq // ENC_FRAMES_DIV, 16),
                                cfg.enc_input_dim), jnp.bfloat16, mesh,
                               P(ba, None, None))}
    return {}


def _param_structs(cfg: ModelConfig, mesh, fsdp: bool, dtype=None):
    shapes = jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    if dtype is not None:  # serving runs bf16 weights
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if s.dtype == jnp.float32 else s.dtype),
            shapes)
    specs = partition.param_specs(cfg, shapes, mesh, fsdp=fsdp)
    return partition.shard_tree(shapes, specs, mesh), specs


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh,
                optim: str = "adamw", fsdp: bool = True,
                remat: bool = True, unroll: bool = False) -> DryrunCase:
    B, S = shape.global_batch, shape.seq_len
    ba = partition.batch_axes(mesh, B)
    params_sds, pspecs = _param_structs(cfg, mesh, fsdp)
    opt = make_optimizer(OptimConfig(kind=optim))
    opt_shapes = jax.eval_shape(opt.init, params_sds)
    ospecs = partition.opt_specs(pspecs, opt_shapes)
    opt_sds = partition.shard_tree(opt_shapes, ospecs, mesh)
    batch = {
        "tokens": _sds((B, S), jnp.int32, mesh, P(ba, None)),
        "labels": _sds((B, S), jnp.int32, mesh, P(ba, None)),
        **_extras_specs(cfg, B, S, mesh, ba),
    }
    oc = OptimConfig(kind=optim)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: tfm.lm_loss(p, cfg, batch, remat=remat,
                                  unroll=unroll))(params)
        grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params, oc.lr)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    return DryrunCase(f"{cfg.name}:{shape.name}", train_step,
                      (params_sds, opt_sds, batch),
                      {"batch": B, "seq": S, "kind": "train",
                       "donate": (0, 1)})


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  fsdp: bool = False, unroll: bool = False) -> DryrunCase:
    B, S = shape.global_batch, shape.seq_len
    ba = partition.batch_axes(mesh, B)
    params_sds, _ = _param_structs(cfg, mesh, fsdp, dtype=jnp.bfloat16)
    tokens = _sds((B, S), jnp.int32, mesh, P(ba, None))
    extras = _extras_specs(cfg, B, S, mesh, ba)

    def prefill_step(params, tokens, extras):
        return tfm.prefill(params, cfg, tokens, extras=extras, max_len=S,
                           unroll=unroll)

    return DryrunCase(f"{cfg.name}:{shape.name}", prefill_step,
                      (params_sds, tokens, extras),
                      {"batch": B, "seq": S, "kind": "prefill"})


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 fsdp: bool = False, unroll: bool = False,
                 cache_seq_shard: bool = False) -> DryrunCase:
    B, S = shape.global_batch, shape.seq_len
    ba = partition.batch_axes(mesh, B)
    params_sds, _ = _param_structs(cfg, mesh, fsdp, dtype=jnp.bfloat16)
    # sub-quadratic long-context serving: ring window cache for attention
    window = SERVE_WINDOW if (S > 65536 and cfg.family != "ssm") else 0
    cache_shapes = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, S, window=window))
    cspecs = partition.cache_specs(cfg, cache_shapes, mesh, B,
                                   seq_shard=cache_seq_shard)
    cache_sds = partition.shard_tree(cache_shapes, cspecs, mesh)
    token = _sds((B, 1), jnp.int32, mesh, P(ba, None))
    ring = bool(window)

    def serve_step(params, cache, token, pos):
        return tfm.decode_step(params, cfg, cache, token, pos, ring=ring,
                               unroll=unroll)

    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return DryrunCase(f"{cfg.name}:{shape.name}", serve_step,
                      (params_sds, cache_sds, token, pos),
                      {"batch": B, "seq": S, "kind": "decode",
                       "window": window, "donate": (1,)})


def scale_config(cfg: ModelConfig, n_blocks: int) -> ModelConfig:
    """Variant of cfg with ``n_blocks`` scanned super-blocks (prefix and
    remainder layers preserved) — used by the 2-point roofline
    extrapolation: cost(k) = base + k * per_block exactly, because scanned
    blocks are identical."""
    import dataclasses
    per = len(cfg.pattern) if cfg.pattern else 1
    prefix = cfg.moe.first_moe_layer if cfg.family == "moe" else 0
    rem = len(cfg.remainder)
    n_layers = prefix + per * n_blocks + rem
    kw = {"n_layers": n_layers}
    if cfg.family == "encdec":
        kw["enc_layers"] = n_blocks
    return dataclasses.replace(cfg, **kw)


def build_sage_serve(cfg: ModelConfig, mesh, k_groups: int = 64,
                     group_n: int = 4, unroll: bool = False,
                     no_tp: bool = False) -> DryrunCase:
    """The paper's own serving step on the production mesh: ONE shared-phase
    DDIM step (CFG over K group latents) + ONE branch-phase step (K*N member
    latents) of Alg. 1 — the two computations whose ratio sets SAGE's cost
    saving.  Latents shard over (pod, data); the DiT shards over model."""
    from repro.config import SageConfig
    from repro.core import samplers
    from repro.core.guidance import cfg_combine
    from repro.core.schedule import make_schedule
    from repro.models import dit as dit_lib

    sched = make_schedule(1000)
    ba = partition.batch_axes(mesh, k_groups)
    shapes = jax.eval_shape(
        lambda: dit_lib.init_params(cfg, jax.random.PRNGKey(0)))
    if no_tp:   # pure data parallel: the 0.45B DiT fits replicated in bf16
        specs = jax.tree.map(lambda s: P(*([None] * len(s.shape))), shapes)
    else:
        specs = partition.param_specs(cfg, shapes, mesh, fsdp=False)
    params_sds = partition.shard_tree(shapes, specs, mesh)
    H = cfg.latent_size
    z_shared = _sds((k_groups, H, H, cfg.latent_channels), jnp.float32,
                    mesh, P(ba, None, None, None))
    z_branch = _sds((k_groups * group_n, H, H, cfg.latent_channels),
                    jnp.float32, mesh, P(ba, None, None, None))
    cbar = _sds((k_groups, cfg.cond_len, cfg.cond_dim), jnp.bfloat16, mesh,
                P(ba, None, None))
    cm = _sds((k_groups * group_n, cfg.cond_len, cfg.cond_dim), jnp.bfloat16,
              mesh, P(ba, None, None))

    def sage_step(params, z_s, z_b, cbar, cm):
        def eps(z, t, c):
            return dit_lib.forward(params, cfg, z, t, c, remat=False)

        def cfg_eval(z, c, t):
            B = z.shape[0]
            zz = jnp.concatenate([z, z], 0)
            cc = jnp.concatenate([jnp.zeros_like(c), c], 0)
            tt = jnp.full((2 * B,), t)
            e = eps(zz, tt, cc)
            return cfg_combine(e[:B], e[B:], 7.5)

        t, tn = jnp.int32(800), jnp.int32(766)
        e_s = cfg_eval(z_s, cbar, t)
        z_s2 = samplers.ddim_step(sched, z_s, t, tn, e_s)
        e_b = cfg_eval(z_b, cm, t)
        z_b2 = samplers.ddim_step(sched, z_b, t, tn, e_b)
        return z_s2, z_b2

    return DryrunCase(f"{cfg.name}:sage_serve", sage_step,
                      (params_sds, z_shared, z_branch, cbar, cm),
                      {"batch": k_groups, "seq": group_n, "kind": "sage"})


_ALLOWED_KW = {
    "train": ("optim", "fsdp", "remat", "unroll"),
    "prefill": ("fsdp", "unroll"),
    "decode": ("fsdp", "unroll", "cache_seq_shard"),
    "sage": ("unroll", "no_tp"),
}


def build_case(arch: str, shape_name: str, mesh, smoke: bool = False,
               n_blocks: Optional[int] = None,
               attn_impl: Optional[str] = None,
               attn_block: int = 0, **kw) -> DryrunCase:
    import dataclasses
    cfg = get_config(arch, smoke=smoke)
    if n_blocks is not None:
        cfg = scale_config(cfg, n_blocks)
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if attn_block:
        cfg = dataclasses.replace(cfg, attn_block=attn_block)
    if shape_name == "sage_serve":
        kw = {k: v for k, v in kw.items() if k in _ALLOWED_KW["sage"]}
        return build_sage_serve(cfg, mesh, **kw)
    shape = SHAPES[shape_name]
    kw = {k: v for k, v in kw.items() if k in _ALLOWED_KW[shape.kind]}
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, **kw)
    return build_decode(cfg, shape, mesh, **kw)
