"""Grouped dataset construction (paper §3.1).

Mirrors the paper's recipe on our procedural corpus: embed all prompts with
the text tower, build the (tau_min, tau_max] threshold graph, enumerate
greedy cliques of 2..group_max members, and emit packed (K, N) training
groups of (latent, cond) pairs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import grouping
from repro.data.synthetic import ShapesDataset


@dataclass
class GroupedDataset:
    images: np.ndarray            # (M, H, W, 3)
    prompts: List[str]
    embeds: np.ndarray            # (M, d)  pooled text embeddings
    cond: np.ndarray              # (M, Lc, dc)  per-token text features
    groups: List[List[int]]       # clique cover

    def packed(self, group_size: int):
        idx, mask = grouping.pad_groups(self.groups, group_size)
        return idx, mask

    def iter_batches(self, k_groups: int, group_size: int, seed: int = 0):
        """Yields {"images": (K,N,H,W,3), "cond": (K,N,Lc,dc), "mask": (K,N)}."""
        idx, mask = self.packed(group_size)
        rng = np.random.RandomState(seed)
        order = rng.permutation(len(idx))
        for s in range(0, len(order) - k_groups + 1, k_groups):
            sel = order[s:s + k_groups]
            gi = idx[sel]                      # (K, N)
            yield {"images": self.images[gi],
                   "cond": self.cond[gi],
                   "mask": mask[sel]}


def build_grouped_dataset(encode_fn, n_items: int = 256, res: int = 64,
                          tau_min: float = 0.6, tau_max: float = 0.9,
                          group_max: int = 5, seed: int = 0
                          ) -> GroupedDataset:
    """encode_fn(prompts) -> (cond (M,Lc,dc), pooled (M,d)) — the text tower."""
    ds = ShapesDataset(res=res, seed=seed)
    images, prompts = ds.batch(0, n_items)
    cond, pooled = encode_fn(prompts)
    cond, pooled = np.asarray(cond), np.asarray(pooled)
    sim = grouping.similarity_matrix(pooled)
    groups = grouping.greedy_clique_groups(sim, tau_min, tau_max,
                                           group_max=group_max)
    return GroupedDataset(images=images, prompts=prompts, embeds=pooled,
                          cond=cond, groups=groups)
