"""Procedural text-image dataset (the offline stand-in for MS-COCO 2017).

Images are anti-aliased renders of colored geometric shapes on colored
backgrounds; prompts are templated captions ("a red circle on a blue
background").  Semantic similarity is *real*: prompts sharing shape/color
attributes produce similar text-tower embeddings, so SAGE's grouping,
shared-phase training and the similarity-range sweeps (paper Fig. 3) all
behave qualitatively like captioned natural images.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

SHAPE_KINDS = ("circle", "square", "triangle", "ring", "cross")
COLORS = {
    "red": (0.9, 0.15, 0.15), "green": (0.1, 0.75, 0.2),
    "blue": (0.15, 0.3, 0.9), "yellow": (0.9, 0.85, 0.1),
    "purple": (0.6, 0.2, 0.8), "orange": (0.95, 0.55, 0.1),
    "white": (0.95, 0.95, 0.95), "teal": (0.1, 0.7, 0.7),
}
SIZES = ("small", "large")


def _render(kind: str, fg, bg, size: str, res: int, jitter_rng) -> np.ndarray:
    y, x = np.mgrid[0:res, 0:res].astype(np.float32) / res - 0.5
    cx, cy = jitter_rng.uniform(-0.12, 0.12, 2)
    x, y = x - cx, y - cy
    r = 0.18 if size == "small" else 0.32
    if kind == "circle":
        m = (x * x + y * y) < r * r
    elif kind == "square":
        m = (np.abs(x) < r) & (np.abs(y) < r)
    elif kind == "triangle":
        m = (y > -r) & (np.abs(x) < (r - y) * 0.6) & (y < r)
    elif kind == "ring":
        d = np.sqrt(x * x + y * y)
        m = (d < r) & (d > r * 0.6)
    else:  # cross
        m = ((np.abs(x) < r * 0.35) & (np.abs(y) < r)) | \
            ((np.abs(y) < r * 0.35) & (np.abs(x) < r))
    img = np.empty((res, res, 3), np.float32)
    img[:] = bg
    img[m] = fg
    noise = jitter_rng.normal(0, 0.02, img.shape).astype(np.float32)
    return np.clip(img + noise, 0.0, 1.0) * 2.0 - 1.0


N_COMBOS = len(SHAPE_KINDS) * len(COLORS) * (len(COLORS) - 1) * len(SIZES)


@dataclass
class ShapesDataset:
    """Deterministic procedural dataset; index -> (image, prompt).

    The first N_COMBOS (=560) indices enumerate UNIQUE attribute combos in a
    seed-shuffled order (duplicate prompts would otherwise dominate the
    similarity graph with sim=1.0 pairs and break the (tau_min, tau_max]
    range semantics); beyond that, prompts repeat with fresh image jitter."""
    res: int = 64
    seed: int = 0

    def sample(self, idx: int) -> Tuple[np.ndarray, str]:
        rng = np.random.RandomState(self.seed * 1_000_003 + idx)
        perm = np.random.RandomState(self.seed).permutation(N_COMBOS)
        r = int(perm[idx % N_COMBOS])
        color_names = list(COLORS)
        nc = len(color_names)
        kind = SHAPE_KINDS[r // (nc * (nc - 1) * 2)]
        r %= nc * (nc - 1) * 2
        fg_i = r // ((nc - 1) * 2)
        r %= (nc - 1) * 2
        bg_i = r // 2
        size = SIZES[r % 2]
        fg_name = color_names[fg_i]
        bg_name = color_names[bg_i + (1 if bg_i >= fg_i else 0)]
        img = _render(kind, COLORS[fg_name], COLORS[bg_name], size, self.res,
                      rng)
        prompt = f"a {size} {fg_name} {kind} on a {bg_name} background"
        return img, prompt

    def batch(self, start: int, n: int):
        imgs, prompts = [], []
        for i in range(start, start + n):
            im, p = self.sample(i)
            imgs.append(im)
            prompts.append(p)
        return np.stack(imgs), prompts


def token_stream(vocab: int, batch: int, seq: int, seed: int = 0):
    """Synthetic LM token batches for the transformer-substrate examples."""
    rng = np.random.RandomState(seed)
    while True:
        t = rng.randint(0, vocab, (batch, seq + 1), dtype=np.int64)
        yield {"tokens": t[:, :-1].astype(np.int32),
               "labels": t[:, 1:].astype(np.int32)}
