from repro.data.synthetic import ShapesDataset
from repro.data.grouped import GroupedDataset, build_grouped_dataset
