"""Dependency-free checkpointing: pytrees -> flat .npz + JSON treedef.

Layout:  <dir>/step_<n>/arrays.npz  +  <dir>/step_<n>/tree.json
Restores onto host then (optionally) device_put with given shardings —
adequate for the single-host substrate here; a real deployment would swap
in tensorstore/orbax behind the same two calls.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    path.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        dtypes.append(str(a.dtype))
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = a.view(np.uint16)         # npz has no bf16; round-trip raw
        arrays[f"a{i}"] = a
    np.savez(path / "arrays.npz", **arrays)
    meta = {"n": len(leaves), "step": step, "dtypes": dtypes}
    (path / "tree.json").write_text(json.dumps(meta))
    return str(path)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = pathlib.Path(ckpt_dir)
    if not p.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in p.iterdir()
             if d.name.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """``like`` supplies the treedef; shardings optionally re-place leaves."""
    import ml_dtypes
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    meta = json.loads((path / "tree.json").read_text())
    n = meta["n"]
    assert n == len(leaves_like), (n, len(leaves_like))
    leaves = []
    for i in range(n):
        a = data[f"a{i}"]
        if meta["dtypes"][i] == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        leaves.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree
