"""Serving-tier observability: request-lifecycle tracing + a metrics
registry — zero-overhead when disabled, deterministic under virtual time.

Seven PRs of serving machinery (scheduler -> policies -> packing -> trunk
cache -> faults -> kernels) report through one end-of-run ``summary()``
dict.  That answers *how much* but never *why*: which request missed its
deadline behind which backlog, which pack bucket carried the pad waste,
which cache lookups fell to the spill tier.  This module adds the two
primitives that answer those questions without perturbing anything:

:class:`Tracer`
    Structured lifecycle spans (``request.submit -> request.admit ->
    request.group -> group.hold -> group.launch -> cache.{exact,ann,miss}
    -> phase.shared -> group.fork -> phase.branch -> request.complete`` /
    ``group.preempt`` / ``group.resume`` — see docs/architecture.md §11
    for the full taxonomy) plus per-tick phase-timing spans, exportable
    as Chrome trace-event JSON (loadable in Perfetto / chrome://tracing).
    Timestamps derive ONLY from the scheduler's injectable ``now`` clock,
    so a virtual-time trace is a pure function of the arrival trace —
    byte-identical across runs and machines.  Events *within* one tick
    are laid out on a deterministic sub-tick slot cursor (1/1024 tick per
    event) so Perfetto renders admission -> launch -> advance -> complete
    as properly nested spans without wall-clock data.  The tracer records
    its own cumulative emit time (``self_seconds``) so the overhead
    contract (< 5% of run wall time) is testable without flaky A/B
    timing.

:class:`MetricsRegistry`
    The single home of the serving stats: counters live in
    :class:`StatGroup` objects — real ``dict`` subclasses, so existing
    ``stats["nfe"] += x`` call sites and every test that reads
    ``sched.stats`` / ``cache.stats`` keep working unchanged at zero
    added cost — plus callable gauges, labeled counter families (per-QoS
    mirrors, per-kind fault counts) and fixed-bucket histograms
    (latency / queue depth / pack occupancy).  ``to_prometheus()`` emits
    the text exposition format (``--metrics out.prom`` in
    ``examples/serve_shared.py``); naming is ``sage_<group>_<key>`` with
    ``_total`` suffixed to counters.

Neither primitive touches jax, RNG streams, or any value the sampler
sees: tracing enabled or disabled is bitwise-invisible to results (the
conformance goldens pin this), and with ``tracer=None`` (the default)
the scheduler's emit sites reduce to one ``is not None`` branch.

:func:`safe_ratio` is the shared divide-by-zero guard for every derived
rate (``launches_per_tick``, ``pad_waste``, hit rates): zero-tick /
zero-row runs uniformly report the default (0.0), never NaN, inf, or a
per-call-site sentinel style.
"""
from __future__ import annotations

import bisect
import json
import time
from collections import Counter, OrderedDict
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Mapping,
                    Optional, Sequence, Tuple)

__all__ = ["safe_ratio", "Histogram", "StatGroup", "MetricsRegistry",
           "Tracer", "TraceEvent", "LATENCY_BUCKETS",
           "QUEUE_DEPTH_BUCKETS", "OCCUPANCY_BUCKETS",
           "PID_REQUESTS", "PID_GROUPS", "PID_EXEC"]


def safe_ratio(num: float, den: float, default: float = 0.0) -> float:
    """``num / den`` with an explicit empty-denominator answer.

    THE divide-by-zero convention for derived serving stats: a rate over
    nothing is ``default`` (0.0 unless stated), never NaN/inf and never
    a mixed bag of per-call-site sentinels."""
    return num / den if den else default


# -- fixed histogram bucket sets (upper bounds; +Inf is implicit) -------
# latencies are virtual ticks (1 tick = 1 time unit on the virtual
# clock); queue depth is waiting requests at tick start; occupancy is
# members/group_size at launch (1.0 = a full group)
LATENCY_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
QUEUE_DEPTH_BUCKETS: Tuple[float, ...] = (0, 1, 2, 4, 8, 16, 32, 64)
OCCUPANCY_BUCKETS: Tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)


class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus semantics).

    ``buckets`` are upper bounds in increasing order; observations above
    the last bound land only in the implicit +Inf bucket.  ``observe``
    is O(log buckets) pure python — cheap enough to stay always-on next
    to the stat deques it summarises."""

    __slots__ = ("buckets", "counts", "total", "sum")

    def __init__(self, buckets: Sequence[float]):
        b = tuple(float(x) for x in buckets)
        if list(b) != sorted(set(b)):
            raise ValueError(f"buckets must be strictly increasing: {b}")
        self.buckets = b
        self.counts = [0] * len(b)        # per-bound cumulative-at-export
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        if i < len(self.counts):
            self.counts[i] += 1
        self.total += 1
        self.sum += v

    def cumulative(self) -> List[Tuple[float, int]]:
        """(upper_bound, cumulative_count) pairs, +Inf last."""
        out, acc = [], 0
        for b, c in zip(self.buckets, self.counts):
            acc += c
            out.append((b, acc))
        out.append((float("inf"), self.total))
        return out


class StatGroup(dict):
    """A registry-owned counter group that IS a plain dict.

    The serving stack mutates its stats with ``stats[k] += v`` from hot
    loops and the test suite reads them as dicts; subclassing ``dict``
    keeps both contracts byte-for-byte while letting the registry
    enumerate and export the group.  No methods are overridden — there
    is deliberately nothing to slow down."""
    __slots__ = ()


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats via repr."""
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _labels(d: Mapping[str, Any]) -> str:
    inner = ",".join(f'{k}="{v}"' for k, v in d.items())
    return "{" + inner + "}" if inner else ""


class MetricsRegistry:
    """Single home for serving metrics: counter groups, gauges, labeled
    families and histograms, with Prometheus text exposition.

    Groups/families are attached *by reference* — the registry never
    copies or wraps the hot-path dicts, it only knows where they live —
    so registration has zero steady-state cost.  Names must be unique
    across all kinds (one exposition namespace).
    """

    def __init__(self, namespace: str = "sage"):
        self.namespace = namespace
        self._groups: "OrderedDict[str, Mapping[str, float]]" = \
            OrderedDict()
        self._gauges: "OrderedDict[str, Callable[[], float]]" = \
            OrderedDict()
        # flat families: name -> (mapping, label key); nested families:
        # prefix -> (mapping-of-dicts, label key)
        self._families: "OrderedDict[str, Tuple[Mapping, str]]" = \
            OrderedDict()
        self._nested: "OrderedDict[str, Tuple[Mapping, str]]" = \
            OrderedDict()
        self._hists: "OrderedDict[str, Histogram]" = OrderedDict()
        self._collectors: List[Callable[[], Iterable]] = []

    # -- registration ---------------------------------------------------
    def _claim(self, name: str) -> None:
        for pool in (self._groups, self._gauges, self._families,
                     self._nested, self._hists):
            if name in pool:
                raise ValueError(
                    f"metric name {name!r} already registered — one "
                    f"registry serves one scheduler/cache/fault set")

    def group(self, prefix: str,
              initial: Mapping[str, float]) -> StatGroup:
        """Create and register a counter group; returns the live
        :class:`StatGroup` the owner mutates directly."""
        sg = StatGroup(initial)
        self.attach_group(prefix, sg)
        return sg

    def attach_group(self, prefix: str,
                     mapping: Mapping[str, float]) -> None:
        """Adopt an existing stats dict (e.g. ``TrunkCache.stats``) as a
        counter group — the registry becomes its export surface without
        the owner changing a line of accounting code."""
        self._claim(prefix)
        self._groups[prefix] = mapping

    def gauge(self, name: str, fn: Callable[[], float]) -> None:
        """Register a point-in-time reading (resolved at export)."""
        self._claim(name)
        self._gauges[name] = fn

    def attach_family(self, name: str, mapping: Mapping[str, float],
                      label: str) -> None:
        """Adopt a flat ``{label_value: count}`` dict as one labeled
        counter family (e.g. ``FaultPlan.injected`` by fault kind)."""
        self._claim(name)
        self._families[name] = (mapping, label)

    def attach_nested(self, prefix: str,
                      mapping: Mapping[str, Mapping[str, float]],
                      label: str) -> None:
        """Adopt a ``{label_value: {key: count}}`` dict-of-dicts (e.g.
        the per-QoS class_stats mirrors) as per-key labeled families."""
        self._claim(prefix)
        self._nested[prefix] = (mapping, label)

    def histogram(self, name: str,
                  buckets: Sequence[float]) -> Histogram:
        self._claim(name)
        h = Histogram(buckets)
        self._hists[name] = h
        return h

    def collector(self, fn: Callable[[], Iterable]) -> None:
        """Register an export-time sample source: ``fn()`` yields
        ``(name, labels_dict, value, type)`` tuples (the hook the
        kernel-dispatch log uses to ride the same .prom file)."""
        self._collectors.append(fn)

    # -- views ----------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Flat ``{qualified_name: value}`` view of everything (labels
        rendered into the key) — the test-facing export."""
        out: Dict[str, float] = {}
        for prefix, m in self._groups.items():
            for k, v in m.items():
                out[f"{prefix}_{k}"] = v
        for name, fn in self._gauges.items():
            out[name] = fn()
        for name, (m, label) in self._families.items():
            for k, v in m.items():
                out[f'{name}{{{label}="{k}"}}'] = v
        for prefix, (m, label) in self._nested.items():
            for lv, sub in m.items():
                for k, v in sub.items():
                    out[f'{prefix}_{k}{{{label}="{lv}"}}'] = v
        for name, h in self._hists.items():
            out[f"{name}_count"] = h.total
            out[f"{name}_sum"] = h.sum
        for fn in self._collectors:
            for name, labels, v, _kind in fn():
                out[f"{name}{_labels(labels or {})}"] = v
        return out

    def to_prometheus(self) -> str:
        """Text exposition format (one namespace, counters suffixed
        ``_total``, histograms with cumulative ``_bucket`` series)."""
        ns, lines = self.namespace, []

        def emit(name: str, kind: str, samples) -> None:
            base = f"{ns}_{name}" + ("_total" if kind == "counter"
                                     else "")
            lines.append(f"# TYPE {base} {kind}")
            for labels, v in samples:
                lines.append(f"{base}{_labels(labels)} {_fmt(v)}")

        for prefix, m in self._groups.items():
            for k, v in m.items():
                emit(f"{prefix}_{k}", "counter", [({}, v)])
        for name, fn in self._gauges.items():
            emit(name, "gauge", [({}, fn())])
        for name, (m, label) in self._families.items():
            emit(name, "counter",
                 [({label: k}, v) for k, v in m.items()])
        for prefix, (m, label) in self._nested.items():
            keys = sorted({k for sub in m.values() for k in sub})
            for k in keys:
                emit(f"{prefix}_{k}", "counter",
                     [({label: lv}, sub.get(k, 0))
                      for lv, sub in m.items()])
        for name, h in self._hists.items():
            base = f"{ns}_{name}"
            lines.append(f"# TYPE {base} histogram")
            for bound, acc in h.cumulative():
                le = "+Inf" if bound == float("inf") else _fmt(bound)
                lines.append(f'{base}_bucket{{le="{le}"}} {acc}')
            lines.append(f"{base}_sum {_fmt(h.sum)}")
            lines.append(f"{base}_count {h.total}")
        for fn in self._collectors:
            for name, labels, v, kind in fn():
                emit(name, kind, [(labels or {}, v)])
        return "\n".join(lines) + "\n"

    def export(self, path: str) -> int:
        """Write the Prometheus exposition; returns the line count."""
        text = self.to_prometheus()
        with open(path, "w") as f:
            f.write(text)
        return text.count("\n")


# -- tracing ------------------------------------------------------------

# process lanes in the exported trace: requests get tid=rid, groups
# tid=gid, exec is the single tick/launch timeline
PID_REQUESTS, PID_GROUPS, PID_EXEC = 1, 2, 3
_PROCESS_NAMES = {PID_REQUESTS: "requests", PID_GROUPS: "groups",
                  PID_EXEC: "exec"}

# sub-tick layout: each exec-lane event occupies one slot of 1/1024
# tick, so phase spans nest their launches and the whole tick stays
# inside [now, now+1) no matter how busy it was (the cursor clamps at
# the last slot — ordering beyond 1022 events/tick piles up, it never
# spills into the next tick)
_SLOT = 1.0 / 1024.0
_MAX_SLOT = 1022


@dataclass
class TraceEvent:
    """One trace event in scheduler-clock units (unscaled)."""
    name: str
    cat: str
    ph: str                       # "X" complete span | "i" instant
    ts: float
    dur: float
    pid: int
    tid: int
    args: Optional[Dict[str, Any]] = None


@dataclass
class Tracer:
    """Collects lifecycle spans; exports Chrome trace-event JSON.

    ``time_scale`` maps scheduler-clock units to microseconds at export
    (default 1e6: one virtual tick renders as one second — readable in
    Perfetto; pass 1.0 when driving with wall-clock seconds... which
    already are microseconds after the 1e6 scale, so leave the default).
    ``max_events`` bounds memory on long-lived servers: past it events
    are dropped (counted in ``dropped``) while ``counts()`` stays exact.

    Overhead accounting: every emit is wrapped in a perf_counter pair
    whose total lands in ``self_seconds`` — the tracer's own cost is
    part of its telemetry, so the < 5% overhead contract is asserted
    directly instead of via flaky A/B wall comparisons.
    """
    time_scale: float = 1e6
    max_events: int = 1 << 20
    events: List[TraceEvent] = field(default_factory=list)
    dropped: int = 0
    self_seconds: float = 0.0

    def __post_init__(self):
        self._counts: Counter = Counter()
        self._base = 0.0              # current tick's ts origin
        self._slot = 0                # sub-tick slot cursor
        self._tick_args: Dict[str, Any] = {}
        self._phase: Optional[str] = None
        self._phase_slot = 0

    # -- core emit -------------------------------------------------------
    def _emit(self, name: str, cat: str, ph: str, ts: float, dur: float,
              pid: int, tid: int,
              args: Optional[Dict[str, Any]]) -> None:
        t0 = time.perf_counter()
        self._counts[name] += 1
        if len(self.events) < self.max_events:
            self.events.append(
                TraceEvent(name, cat, ph, ts, dur, pid, tid, args))
        else:
            self.dropped += 1
        self.self_seconds += time.perf_counter() - t0

    def instant(self, name: str, ts: float, *, pid: int, tid: int,
                cat: str = "lifecycle", **args: Any) -> None:
        """A zero-duration lifecycle mark at an explicit scheduler-clock
        timestamp (request/group lanes)."""
        self._emit(name, cat, "i", ts, 0.0, pid, tid, args or None)

    def span(self, name: str, ts: float, dur: float, *, pid: int,
             tid: int, cat: str = "lifecycle", **args: Any) -> None:
        """A duration span at explicit scheduler-clock bounds."""
        self._emit(name, cat, "X", ts, dur, pid, tid, args or None)

    # -- exec-lane tick structure ---------------------------------------
    def _cursor(self) -> int:
        s = self._slot
        if self._slot < _MAX_SLOT:
            self._slot += 1
        return s

    def tick_begin(self, now: float, tick: int) -> None:
        """Open a tick frame: subsequent exec-lane events lay out on the
        sub-tick slot cursor starting at ``now``."""
        self._base = float(now)
        self._slot = 0
        self._phase = None
        self._tick_args = {"tick": tick}

    def phase_begin(self, name: str) -> None:
        """Open a tick phase (closing any still-open one first, so the
        scheduler's admit -> launch -> advance -> complete sections each
        call only ``phase_begin``)."""
        self.phase_end()
        self._phase = name
        self._phase_slot = self._slot

    def phase_end(self) -> None:
        """Close the open tick phase as a span covering every slot its
        events consumed (at least one, so empty phases stay visible)."""
        if self._phase is None:
            return
        start = self._phase_slot
        end = max(self._slot, start + 1)
        self._slot = end
        self._emit(f"tick.{self._phase}", "tick", "X",
                   self._base + start * _SLOT, (end - start) * _SLOT,
                   PID_EXEC, 0, None)
        self._phase = None

    def exec_mark(self, name: str, **args: Any) -> None:
        """Instant on the exec lane at the next sub-tick slot."""
        self._emit(name, "exec", "i", self._base + self._cursor() * _SLOT,
                   0.0, PID_EXEC, 0, args or None)

    def launch_span(self, name: str, **args: Any) -> None:
        """One segment launch: a one-slot span on the exec lane, nested
        inside the current tick phase."""
        self._emit(name, "exec", "X",
                   self._base + self._cursor() * _SLOT, _SLOT,
                   PID_EXEC, 0, args or None)

    def tick_end(self, **args: Any) -> None:
        """Close the tick frame as a span over all consumed slots."""
        self.phase_end()
        a = dict(self._tick_args)
        a.update(args)
        self._emit("tick", "tick", "X", self._base,
                   max(self._slot, 1) * _SLOT, PID_EXEC, 0, a)

    # -- views & export --------------------------------------------------
    def counts(self) -> Dict[str, int]:
        """Exact per-name event counts (unaffected by ``max_events``
        drops) — what the reconciliation tests compare to ``stats``."""
        return dict(self._counts)

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (ts/dur in microseconds)."""
        sc = self.time_scale
        evs: List[Dict[str, Any]] = []
        for pid, pname in _PROCESS_NAMES.items():
            evs.append({"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0, "args": {"name": pname}})
        for e in self.events:
            d: Dict[str, Any] = {"name": e.name, "cat": e.cat,
                                 "ph": e.ph, "ts": e.ts * sc,
                                 "pid": e.pid, "tid": e.tid}
            if e.ph == "X":
                d["dur"] = e.dur * sc
            else:
                d["s"] = "t"       # instant scope: thread
            if e.args:
                d["args"] = e.args
            evs.append(d)
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export(self, path: str) -> int:
        """Write Perfetto-loadable JSON; returns the event count."""
        obj = self.to_chrome()
        with open(path, "w") as f:
            json.dump(obj, f)
        return len(obj["traceEvents"])
