"""SAGE's insight mapped to autoregressive serving (DESIGN.md §4).

The paper amortises the early, semantically-coarse part of generation
across similar queries.  For AR transformers the exact analogue is a
*shared trunk*: group requests by prompt-embedding similarity, run ONE
prefill over the group's common trunk, fork the KV/state cache at the
branch point, then decode each member with its own continuation.

Two trunk definitions are provided:
* exact common prefix (lossless — identical logits, pure win;
  vLLM-style prefix caching but *selected by semantic grouping*);
* truncated trunk at the SAGE branch ratio for near-identical prompts
  (lossy, flagged experimental — the AR twin of the paper's shared phase).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core import grouping
from repro.serving.kvcache import fork_model_cache


def common_prefix_len(token_rows: np.ndarray) -> int:
    """token_rows (N, S) -> length of the longest shared prefix."""
    if len(token_rows) == 1:
        return token_rows.shape[1]
    eq = np.all(token_rows == token_rows[0:1], axis=0)
    nz = np.nonzero(~eq)[0]
    return int(nz[0]) if len(nz) else token_rows.shape[1]


def group_requests(embeds: np.ndarray, tau: float, group_max: int = 8
                   ) -> List[List[int]]:
    """Semantic grouping of pending requests (paper §2.2, greedy cliques).

    Edge semantics — which similarities count as "similar enough" — are
    defined once in ``core.grouping.edge_mask`` ((tau, tau_max] with the
    duplicate-friendly ``DEFAULT_TAU_MAX``), not re-encoded here."""
    sim = grouping.similarity_matrix(embeds)
    return grouping.greedy_clique_groups(sim, tau, group_max=group_max)


def shared_prefix_prefill(prefill_fn: Callable, decode_fn: Callable,
                          tokens: np.ndarray, max_len: int
                          ) -> Tuple[Any, Any, int, Dict]:
    """One group: prefill the shared trunk once, fork, catch up members.

    prefill_fn(tokens (1, P), max_len) -> (logits, cache)
    decode_fn(cache, token (N, 1), pos) -> (logits, cache)

    Returns (logits, caches, next_pos, stats).  Cost: P + N*(S-P) token
    steps instead of N*S — the AR cost-saving mirror of the paper's
    K(T-T*) + N T* accounting.
    """
    N, S = tokens.shape
    P = common_prefix_len(tokens)
    P = max(1, min(P, S - 1))            # leave >= 1 token to catch up
    logits, trunk = prefill_fn(tokens[:1, :P], max_len)
    caches = fork_model_cache(trunk, N)
    import jax.numpy as jnp
    logits = jnp.repeat(logits, N, axis=0)
    for pos in range(P, S):
        logits, caches = decode_fn(caches, tokens[:, pos:pos + 1],
                                   jnp.int32(pos))
    naive = N * S
    ours = P + N * (S - P)
    return logits, caches, S, {
        "prefix_len": P, "token_steps": ours, "token_steps_naive": naive,
        "saving": 1.0 - ours / naive}
