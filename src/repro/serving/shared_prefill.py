"""SAGE's insight mapped to autoregressive serving (DESIGN.md §4).

The paper amortises the early, semantically-coarse part of generation
across similar queries.  For AR transformers the exact analogue is a
*shared trunk*: group requests by prompt-embedding similarity, run ONE
prefill over the group's common trunk, fork the KV/state cache at the
branch point, then decode each member with its own continuation.

Two trunk definitions are provided:
* exact common prefix (lossless — identical logits, pure win;
  vLLM-style prefix caching but *selected by semantic grouping*);
* truncated trunk at the SAGE branch ratio for near-identical prompts
  (lossy, flagged experimental — the AR twin of the paper's shared phase).

Cross-batch reuse rides the SAME semantic cache as diffusion trunks:
:func:`cached_prefix_prefill` stores the prefill's (logits, kv-cache)
state in a :class:`~repro.serving.trunk_cache.TrunkCache` under
``payload="ar_prefix"`` — the payload field namespaces the key, so one
reuse layer (one byte budget, one admission policy, one ANN index, one
tier ledger) serves both workload kinds without their entries ever
satisfying each other's lookups.  Unlike diffusion trunks, prefix reuse
is *lossless*: the trunk token bytes ride the ``cfg_key``, so only an
exact trunk match hits; the centroid similarity merely routes the
lookup (and lets an LSH index find the entry sub-linearly).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import grouping
from repro.serving.kvcache import fork_model_cache
from repro.serving.trunk_cache import TrunkCache, TrunkEntry, _unit


def common_prefix_len(token_rows: np.ndarray) -> int:
    """token_rows (N, S) -> length of the longest shared prefix."""
    if len(token_rows) == 1:
        return token_rows.shape[1]
    eq = np.all(token_rows == token_rows[0:1], axis=0)
    nz = np.nonzero(~eq)[0]
    return int(nz[0]) if len(nz) else token_rows.shape[1]


def group_requests(embeds: np.ndarray, tau: float, group_max: int = 8
                   ) -> List[List[int]]:
    """Semantic grouping of pending requests (paper §2.2, greedy cliques).

    Edge semantics — which similarities count as "similar enough" — are
    defined once in ``core.grouping.edge_mask`` ((tau, tau_max] with the
    duplicate-friendly ``DEFAULT_TAU_MAX``), not re-encoded here."""
    sim = grouping.similarity_matrix(embeds)
    return grouping.greedy_clique_groups(sim, tau, group_max=group_max)


def shared_prefix_prefill(prefill_fn: Callable, decode_fn: Callable,
                          tokens: np.ndarray, max_len: int
                          ) -> Tuple[Any, Any, int, Dict]:
    """One group: prefill the shared trunk once, fork, catch up members.

    prefill_fn(tokens (1, P), max_len) -> (logits, cache)
    decode_fn(cache, token (N, 1), pos) -> (logits, cache)

    Returns (logits, caches, next_pos, stats).  Cost: P + N*(S-P) token
    steps instead of N*S — the AR cost-saving mirror of the paper's
    K(T-T*) + N T* accounting.
    """
    N, S = tokens.shape
    P = common_prefix_len(tokens)
    P = max(1, min(P, S - 1))            # leave >= 1 token to catch up
    logits, trunk = prefill_fn(tokens[:1, :P], max_len)
    caches = fork_model_cache(trunk, N)
    import jax.numpy as jnp
    logits = jnp.repeat(logits, N, axis=0)
    for pos in range(P, S):
        logits, caches = decode_fn(caches, tokens[:, pos:pos + 1],
                                   jnp.int32(pos))
    naive = N * S
    ours = P + N * (S - P)
    return logits, caches, S, {
        "prefix_len": P, "token_steps": ours, "token_steps_naive": naive,
        "saving": 1.0 - ours / naive}


# -- cross-batch prefix reuse (unified trunk cache) --------------------------

def prefix_cache_key(trunk_tokens: np.ndarray, max_len: int) -> Hashable:
    """Compatibility fingerprint for an AR prefix trunk.  The trunk's
    token bytes are IN the key: an ``ar_prefix`` hit is exact-match on
    the tokens that built the kv-cache, which is what makes reuse
    lossless (the semantic centroid only routes the lookup)."""
    t = np.ascontiguousarray(np.asarray(trunk_tokens, np.int32))
    return ("ar_prefix", int(max_len), t.shape[-1], t.tobytes())


def cached_prefix_prefill(prefill_fn: Callable, decode_fn: Callable,
                          tokens: np.ndarray, max_len: int, *,
                          cache: Optional[TrunkCache],
                          embeds: Optional[np.ndarray] = None,
                          centroid: Optional[np.ndarray] = None
                          ) -> Tuple[Any, Any, int, Dict]:
    """:func:`shared_prefix_prefill` with the trunk served from / stored
    into the unified semantic cache (``payload="ar_prefix"``).

    ``centroid`` (or the mean of ``embeds``) is the group's semantic key
    — the same routing signal diffusion trunks use — while the trunk
    token bytes in ``cfg_key`` keep reuse exact.  On a hit the P prefill
    token-steps vanish from the cost ledger; on a miss the freshly
    computed (logits, kv-cache) pair is inserted for the next wave.
    ``cache=None`` degrades to the uncached fast path.

    Returns ``(logits, caches, next_pos, stats)``; stats add
    ``trunk_cache_hit`` to the usual accounting.
    """
    if centroid is None:
        if embeds is None:
            raise ValueError("need embeds or centroid for cache routing")
        centroid = np.asarray(embeds, np.float32).mean(axis=0)
    centroid = _unit(centroid)
    N, S = tokens.shape
    P = common_prefix_len(tokens)
    P = max(1, min(P, S - 1))            # leave >= 1 token to catch up
    cfg_key = prefix_cache_key(tokens[0, :P], max_len)
    entry = None
    if cache is not None:
        entry = cache.lookup(centroid, 0.0, cfg_key, (P,),
                             payload="ar_prefix")
    import jax.numpy as jnp
    if entry is not None:
        logits, trunk = entry.z
        logits = jnp.asarray(logits)
    else:
        logits, trunk = prefill_fn(tokens[:1, :P], max_len)
        if cache is not None:
            cache.insert(TrunkEntry(
                z=(logits, trunk), eps_prev=None, step_idx=P,
                beta_bucket=0.0, rng_fold=0, centroid=centroid,
                cfg_key=cfg_key, payload="ar_prefix"), shape=(P,))
    caches = fork_model_cache(trunk, N)
    logits = jnp.repeat(logits, N, axis=0)
    for pos in range(P, S):
        logits, caches = decode_fn(caches, tokens[:, pos:pos + 1],
                                   jnp.int32(pos))
    naive = N * S
    ours = (0 if entry is not None else P) + N * (S - P)
    return logits, caches, S, {
        "prefix_len": P, "token_steps": ours, "token_steps_naive": naive,
        "saving": 1.0 - ours / naive,
        "trunk_cache_hit": entry is not None}
