"""SLO / capacity report generation over serving telemetry.

Joins the three telemetry surfaces of one run — the scheduler's
``summary()`` rollup (a :class:`~repro.serving.telemetry.MetricsRegistry`
view), the :class:`~repro.serving.telemetry.Tracer` event counts, and the
kernel :data:`~repro.kernels.dispatch.DISPATCH_LOG` — into the two
documents an operator actually reads:

``slo_report``
    Per-QoS-class goodput / latency / outcome breakdown, cache
    efficiency per tier, pad-waste economics, and (when a tracer is
    supplied) the request-conservation check: every submitted request
    must be accounted for as completed, shed, rejected, or still
    pending — a trace that doesn't reconcile is a scheduler bug, so the
    report surfaces the residual instead of hiding it.

``capacity_report``
    The ROADMAP carry-over lever: the ``launch/dryrun.py`` cost model
    (via the import-safe ``launch/costs.py`` — importing dryrun itself
    would force 512 host devices into XLA_FLAGS) predicts
    ticks-to-drain and NFE for the observed request count, and the
    report prints predicted vs. observed with the gap attributed to
    queueing/holds/retries (ticks) and cache savings (NFE).

``attributed_columns``
    The BENCH hook: extra ``k=v`` tokens for ``benchmarks/*`` rows.
    ``run.py --check`` matches rows by name and pins only ``nfe=`` (plus
    a time tolerance), so adding derived tokens is check-compatible by
    construction — see ``benchmarks/README.md``.

Everything here is pure-dict arithmetic over already-collected numbers:
no jax import, no side effects, safe to run in CI on the text artifacts.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional

from repro.launch.costs import (denoiser_flops_per_eval, predict_drain,
                                roofline_seconds)
from repro.serving.telemetry import safe_ratio

__all__ = ["slo_report", "capacity_report", "attributed_columns",
           "dispatch_report", "format_report"]

#: summary() outcome keys mirrored per class (``{qos}_{key}``)
_CLASS_KEYS = ("completed", "shed", "degraded", "preemptions",
               "deadline_met", "deadline_missed")


def _classes(summary: Mapping[str, Any]) -> List[str]:
    """QoS classes present in a summary (detected from the per-class
    latency keys the scheduler emits for every class it saw)."""
    suffix = "_latency_p50"
    return sorted(k[:-len(suffix)] for k in summary
                  if k.endswith(suffix) and not k.startswith("latency"))


def slo_report(summary: Mapping[str, Any],
               counts: Optional[Mapping[str, int]] = None,
               pending: int = 0) -> Dict[str, Any]:
    """Per-class SLO breakdown + cache efficiency from one run's
    ``summary()``; pass ``tracer.counts()`` (and the scheduler's
    ``pending``) to add the trace-side conservation check."""
    s = summary
    rep: Dict[str, Any] = {
        "overall": {
            "requests": s.get("requests", 0),
            "completed": s.get("completed", 0),
            "goodput": s.get("goodput", s.get("completed", 0)),
            "goodput_per_tick": s.get("goodput_per_tick", 0.0),
            "acceptance": safe_ratio(s.get("completed", 0),
                                     s.get("requests", 0)),
            "latency_p50": s.get("latency_p50", 0.0),
            "latency_p95": s.get("latency_p95", 0.0),
            "cost_saving": s.get("cost_saving", 0.0),
            "nfe_per_request": s.get("nfe_per_request", 0.0),
            "launches_per_tick": s.get("launches_per_tick", 0.0),
            "pad_waste": s.get("pad_waste", 0.0),
            "ticks": s.get("ticks", 0),
        },
        "classes": {},
    }
    for q in _classes(s):
        row = {k: s.get(f"{q}_{k}", 0) for k in _CLASS_KEYS}
        row["latency_p50"] = s.get(f"{q}_latency_p50", 0.0)
        row["latency_p95"] = s.get(f"{q}_latency_p95", 0.0)
        row["goodput"] = row["deadline_met"]
        rep["classes"][q] = row
    if "cache_hits" in s:
        hits, misses = s["cache_hits"], s.get("cache_misses", 0)
        lookups = hits + misses if misses else None
        rep["cache"] = {
            "hits": hits,
            "exact_hits": s.get("cache_exact_hits", 0),
            "ann_hits": hits - s.get("cache_exact_hits", 0),
            "hits_hbm": s.get("cache_hits_hbm", 0),
            "hits_host": s.get("cache_hits_host", 0),
            "hit_rate": s.get("cache_hit_rate", 0.0),
            "nfe_saved": s.get("nfe_saved_cache", 0),
            "spills": s.get("cache_spills", 0),
            "promotions": s.get("cache_promotions", 0),
            "index": s.get("cache_index", "scan"),
        }
        if lookups is not None:
            rep["cache"]["lookups"] = lookups
    if counts is not None:
        submits = counts.get("request.submit", 0)
        accounted = (counts.get("request.complete", 0)
                     + counts.get("request.shed", 0)
                     + counts.get("request.shed_faulted", 0)
                     + counts.get("request.rejected_expired", 0)
                     + pending)
        rep["conservation"] = {
            "submits": submits,
            "completes": counts.get("request.complete", 0),
            "sheds": (counts.get("request.shed", 0)
                      + counts.get("request.shed_faulted", 0)),
            "rejects": counts.get("request.rejected_expired", 0),
            "pending": pending,
            "residual": submits - accounted,   # 0 on a sound trace
        }
    return rep


def capacity_report(summary: Mapping[str, Any], *, total_steps: int,
                    share_ratio: float, group_size: int,
                    slice_steps: int,
                    max_groups_per_tick: Optional[int] = None,
                    n_params: Optional[float] = None,
                    n_tokens: int = 0,
                    chips: int = 1) -> Dict[str, Any]:
    """Predicted vs. observed tick economics (the dryrun cost model wired
    to the scheduler).  ``n_params``/``n_tokens`` (the DiT's analytic
    parameter count and latent token count) add a roofline seconds-per-
    request floor; omit them for the tick-economics-only report."""
    from repro.core.shared_sampling import phase_split
    n_shared, _ = phase_split(total_steps, share_ratio)
    requests = int(summary.get("requests", 0))
    pred = predict_drain(requests, group_size, total_steps, n_shared,
                         slice_steps,
                         max_groups_per_tick=max_groups_per_tick)
    obs_ticks = int(summary.get("ticks", 0))
    obs_nfe = float(summary.get("nfe", 0))
    # predict_drain counts SOLVER steps; the scheduler's NFE ledger
    # counts denoiser evals (2x under CFG, (N+1)/2N-ish with the shared
    # uncond pass).  Scale the prediction by the observed evals-per-step
    # factor so the NFE gap attributes scheduling effects, not units.
    evals_per_step = safe_ratio(
        float(summary.get("nfe_independent", 0)),
        requests * total_steps, default=1.0) or 1.0
    rep: Dict[str, Any] = {
        "model": {
            "requests": requests, "group_size": group_size,
            "total_steps": total_steps, "n_shared": n_shared,
            "slice_steps": slice_steps,
            "max_groups_per_tick": max_groups_per_tick,
            "evals_per_step": evals_per_step,
        },
        "predicted": {
            "groups": pred.groups,
            "ticks_to_drain": pred.ticks,
            "nfe": pred.nfe * evals_per_step,
            "nfe_independent": pred.nfe_independent * evals_per_step,
        },
        "observed": {
            "ticks": obs_ticks,
            "nfe": obs_nfe,
            "nfe_independent": summary.get("nfe_independent", 0),
        },
        # the gaps ARE the report: positive tick gap = queueing + holds
        # + retries + stalls; negative NFE gap = cache savings (and
        # degraded-mode beta boosts); positive = pad/retry waste
        "gaps": {
            "extra_ticks": obs_ticks - pred.ticks,
            "tick_ratio": safe_ratio(obs_ticks, pred.ticks),
            "nfe_delta": obs_nfe - pred.nfe * evals_per_step,
            "nfe_ratio": safe_ratio(obs_nfe, pred.nfe * evals_per_step),
            "nfe_saved_cache": summary.get("nfe_saved_cache", 0),
            "nfe_wasted": summary.get("nfe_wasted", 0),
            "stalled_ticks": summary.get("stalled_ticks", 0),
        },
    }
    if n_params and n_tokens:
        flops_eval = denoiser_flops_per_eval(n_params, n_tokens)
        rep["roofline"] = {
            "flops_per_eval": flops_eval,
            "seconds_per_request_floor": roofline_seconds(
                flops_eval * safe_ratio(obs_nfe or pred.nfe,
                                        max(requests, 1)),
                chips=chips),
        }
    return rep


def dispatch_report(log=None) -> Dict[str, Any]:
    """Kernel route-decision rollup from the (module-global by default)
    dispatch log: every (op, requested→chosen) route with its count,
    fallbacks split out — the live fallback matrix."""
    if log is None:
        from repro.kernels.dispatch import DISPATCH_LOG as log  # noqa: N813
    rows = log.snapshot()
    return {"enabled": log.enabled, "routes": rows,
            "fallbacks": [r for r in rows if r["reason"] != "requested"],
            "fallback_launches": sum(
                r["count"] for r in rows if r["reason"] != "requested")}


def attributed_columns(summary: Mapping[str, Any]) -> str:
    """Extra ``k=v`` tokens for a BENCH row (goodput / pad / cache
    attribution).  Token-append only: ``run.py --check`` pins row name
    and ``nfe=``, so these columns never perturb the gate."""
    toks = [f"goodput={int(summary.get('goodput', summary.get('completed', 0)))}",
            f"launches_per_tick={summary.get('launches_per_tick', 0.0):.2f}",
            f"pad_waste={summary.get('pad_waste', 0.0):.3f}"]
    if "cache_hit_rate" in summary:
        toks.append(f"cache_hit_rate={summary['cache_hit_rate']:.3f}")
        toks.append(f"cache_hbm_hits={int(summary.get('cache_hits_hbm', 0))}")
        toks.append(f"cache_host_hits={int(summary.get('cache_hits_host', 0))}")
    return " ".join(toks)


def _fmt_num(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.3f}" if abs(v) < 1e6 else f"{v:.3e}"
    return str(v)


def _kv_lines(d: Mapping[str, Any], indent: str = "  ") -> List[str]:
    return [f"{indent}{k:<24} {_fmt_num(v)}" for k, v in d.items()]


def format_report(slo: Mapping[str, Any],
                  capacity: Optional[Mapping[str, Any]] = None,
                  dispatch: Optional[Mapping[str, Any]] = None) -> str:
    """Render the joined report as the text block ``serve_shared.py
    --report`` prints."""
    lines: List[str] = ["== SLO report =="]
    lines += _kv_lines(slo["overall"])
    for q, row in sorted(slo.get("classes", {}).items()):
        lines.append(f" class {q}:")
        lines += _kv_lines(row, indent="   ")
    if "cache" in slo:
        lines.append(" cache:")
        lines += _kv_lines(slo["cache"], indent="   ")
    if "conservation" in slo:
        lines.append(" conservation (trace):")
        lines += _kv_lines(slo["conservation"], indent="   ")
    if capacity is not None:
        lines.append("== capacity (dryrun cost model) ==")
        for sect in ("model", "predicted", "observed", "gaps",
                     "roofline"):
            if sect in capacity:
                lines.append(f" {sect}:")
                lines += _kv_lines(capacity[sect], indent="   ")
    if dispatch is not None:
        lines.append("== kernel dispatch ==")
        if not dispatch.get("enabled", False):
            lines.append("  (dispatch log disabled)")
        for r in dispatch.get("routes", []):
            mark = "" if r["reason"] == "requested" else "  <- FALLBACK"
            lines.append(
                f"  {r['op']:<16} {r['requested']:>9} -> "
                f"{r['chosen']:<9} x{r['count']:<6} "
                f"[{r['shape']}] {r['reason']}{mark}")
        lines.append(
            f"  fallback_launches={dispatch.get('fallback_launches', 0)}")
    return "\n".join(lines)
