"""Packed multi-group tick execution — gather, advance, scatter.

PR 3's tick loop advanced every in-flight group with its own ``(1, N)``
denoiser call, so a tick over G concurrent groups paid G launches of a
small batch each: under concurrent load the hot path is *launch*-bound,
not FLOP-bound, and SAGE's shared-trunk savings drown in dispatch
overhead (the serving gap surveyed in "Efficient Diffusion Models: A
Survey"; the same cross-query batching lever as set-generation
computation reuse, arXiv 2508.21032).

This module inverts that execution model: groups no longer own their
launches.  Each tick, in-flight groups are bucketed by a **pack
signature** — everything that must agree for their rows to ride one
phase call:

* ``phase``   — ``shared`` rows advance under the group-mean conditioning,
  ``branch`` rows under per-member conditioning (different call graphs);
* ``sampler`` — the group's OWN solver (requests pick ddim/dpmpp at
  submit); with ``mix_samplers=True`` the component collapses to ``"*"``
  and rows of different solvers share the launch via the per-row
  dispatch in ``shared_sampling`` (``row_samplers`` — see
  :func:`pack_samplers`);
* ``shape``   — the group's OWN latent (H, W, C): requests pick their
  resolution/aspect at submit and groups never mix shapes, so a hetero
  tick launches one stacked call per shape bucket with per-bucket pads
  (SDXL-style multi-resolution serving);
* ``n_steps`` — the segment length every row advances this tick,
  ``min(slice_steps, steps remaining in the phase)``, so no group is
  dragged past its phase boundary by a pack-mate.

The share-ratio bucket (beta) is deliberately NOT part of the signature:
it only determines a group's branch point, which already rides in the
per-row ``step_idx``/``fork_idx`` vectors — groups from different beta
buckets whose segments line up share one launch (this is what lets
``RequestScheduler.run_batch`` issue ONE stacked launch per phase per
tick across its beta buckets instead of one per bucket).  A group's
**total step budget** (quality tier: draft/standard/premium NFE) is not
a signature axis either: each row gathers timesteps from its own
group's DDIM grid (:func:`pack_grid` stacks the per-row grids), so
groups running different ``total_steps`` co-pack whenever their segment
lengths line up — this is what lets a degraded (draft-tier) group share
a launch with standard-tier traffic.

``build_packs(..., align_phases=True)`` additionally aligns the segment
length *within each phase* to the minimum steps remaining among that
phase's groups, collapsing the signature space to at most one bucket per
phase per tick — the synchronous ``run_batch`` drain uses this (it has no
arrival latency to protect, so maximal stacking is free); the streaming
tick loop keeps fixed ``slice_steps`` segments so a long phase cannot
starve the tick cadence.

One bucket becomes ONE ``shared_phase``/``branch_phase`` call over a
stacked :class:`~repro.core.shared_sampling.SampleCarry`: per-row
``step_idx`` (and per-row ``fork_idx`` for branch) carry each group's
grid position as traced values, so buckets with the same (phase,
n_steps, row count) hit the same jit cache entry regardless of where on
the grid their groups sit.  Branch rows are padded to the scheduler's
static width N (mask 0, member-0 replicas — the ``pad_groups``
convention), which buys a fixed launch shape at the price of **pad
waste**; :func:`pad_stats` reports that tradeoff and the scheduler
surfaces it in ``summary()``.

Parity contract (enforced by ``tests/test_conformance.py``): packing is
bitwise-invisible — packed rows reproduce the per-group segment results
EXACTLY for ddim+dpmpp × reference+fused across slice boundaries.  The
ingredients: the denoiser treats batch rows independently, masked group
means ignore appended pad rows exactly, and the per-row step kernels
(``kernels/*_step``) apply the same per-element arithmetic as the
broadcast-scalar launches.

Groups are duck-typed: anything with ``carry`` / ``cbar`` / ``cond_flat``
/ ``members`` / ``steps_done`` / ``n_shared`` / ``beta`` / ``state``
plus the hetero axes ``shape`` / ``sampler`` / ``total_steps``
(see ``scheduler._Group``) packs.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.schedule import ddim_timesteps
from repro.core.shared_sampling import SampleCarry

MIXED = "*"    # PackKey.sampler wildcard under mix_samplers


class PackKey(NamedTuple):
    """Pack-compatibility signature (see module docstring for the rules)."""
    phase: str                  # "shared" | "branch"
    sampler: str                # solver name, or "*" under mix_samplers
    shape: Tuple[int, ...]      # the bucket's latent (H, W, C)
    n_steps: int                # segment length this tick


def phase_remaining(g) -> int:
    """Steps left in group ``g``'s current phase (``g.total_steps`` is the
    group's own tier budget, not a deployment constant)."""
    limit = g.n_shared if g.state == "shared" else g.total_steps
    return limit - g.steps_done


def pack_signature(g, slice_steps: int, mix_samplers: bool = False,
                   n_steps: Optional[int] = None) -> PackKey:
    """The signature under which group ``g`` may share a launch this tick.

    ``n_steps`` overrides the per-group ``min(slice_steps, remaining)``
    segment rule — :func:`build_packs` passes the phase-aligned length
    under ``align_phases``."""
    if n_steps is None:
        n_steps = min(slice_steps, phase_remaining(g))
    return PackKey(g.state, MIXED if mix_samplers else g.sampler,
                   tuple(g.shape), n_steps)


def build_packs(groups: Sequence, slice_steps: int,
                mix_samplers: bool = False,
                align_phases: bool = False,
                order_key=None) -> List[Tuple[PackKey, List]]:
    """Bucket in-flight groups by pack signature (insertion-ordered, so
    the priority sort of the caller — (qos, deadline) under the default
    launch order — is preserved within and across buckets).

    ``align_phases=True`` sets every group's segment length to the
    minimum steps remaining among its phase-mates (still capped by
    ``slice_steps``), so each phase collapses to ONE bucket — no group is
    dragged past its phase boundary, groups merely stop together at the
    earliest one.  The synchronous ``run_batch`` drain uses this to issue
    one stacked launch per phase per tick across beta buckets.

    ``order_key`` (a group -> sort-key callable, e.g. a
    ``serving.policies`` launch order) stable-sorts each bucket's rows —
    the class-aware pack-ordering guarantee: rows inside a launch sit in
    priority order even if the caller's ``groups`` list was not already
    sorted.  A caller that pre-sorted by the same key sees a no-op (the
    sort is stable), so the scheduler's packed results are unchanged.
    """
    phase_steps: Dict[str, int] = {}
    if align_phases:
        for g in groups:
            r = min(slice_steps, phase_remaining(g))
            phase_steps[g.state] = min(phase_steps.get(g.state, r), r)
    packs: Dict[PackKey, List] = {}
    for g in groups:
        packs.setdefault(
            pack_signature(g, slice_steps, mix_samplers,
                           n_steps=phase_steps.get(g.state)),
            []).append(g)
    if order_key is not None:
        for gs in packs.values():
            gs.sort(key=order_key)
    return list(packs.items())


def _pad_rows(x: jnp.ndarray, width: int) -> jnp.ndarray:
    """Pad the leading axis to ``width`` with member-0 replicas (masked
    out of every reduction — same convention as ``grouping.pad_groups``)."""
    n = x.shape[0]
    if n == width:
        return x
    return jnp.concatenate(
        [x, jnp.broadcast_to(x[:1], (width - n,) + x.shape[1:])], 0)


# -- shared phase ------------------------------------------------------------

def pack_shared(groups: Sequence) -> Tuple[SampleCarry, jnp.ndarray]:
    """Stack G shared-phase groups (one trunk row each) into a (G, ...)
    carry with per-row step_idx, plus the stacked (G, Lc, dc) c̄."""
    z = jnp.concatenate([g.carry.z for g in groups], 0)
    ep = jnp.concatenate([g.carry.eps_prev for g in groups], 0)
    step = jnp.asarray([g.steps_done for g in groups], jnp.int32)
    cbar = jnp.concatenate([g.cbar for g in groups], 0)
    return SampleCarry(z, ep, step), cbar


def unpack_shared(carry: SampleCarry, groups: Sequence) -> None:
    """Scatter a packed shared-phase result back into per-group carries."""
    for j, g in enumerate(groups):
        g.carry = SampleCarry(carry.z[j:j + 1], carry.eps_prev[j:j + 1],
                              carry.step_idx[j])


# -- branch phase ------------------------------------------------------------

def pack_branch(groups: Sequence, width: int
                ) -> Tuple[SampleCarry, jnp.ndarray, jnp.ndarray,
                           jnp.ndarray]:
    """Stack G branch-phase groups into a (G*width, ...) carry.

    Every group is padded to the static member width (pad rows replicate
    member 0 and are masked); returns ``(carry, cond_flat, mask,
    fork_idx)`` ready for one ``branch_phase`` call — ``step_idx`` and
    ``fork_idx`` are per-row (G*width,) vectors.
    """
    z = jnp.concatenate([_pad_rows(g.carry.z, width) for g in groups], 0)
    ep = jnp.concatenate([_pad_rows(g.carry.eps_prev, width)
                          for g in groups], 0)
    cond = jnp.concatenate([_pad_rows(g.cond_flat, width) for g in groups],
                           0)
    mask = np.zeros((len(groups), width), np.float32)
    for j, g in enumerate(groups):
        mask[j, :len(g.members)] = 1.0
    step = jnp.asarray(np.repeat([g.steps_done for g in groups], width),
                       jnp.int32)
    fork = jnp.asarray(np.repeat([g.n_shared for g in groups], width),
                       jnp.int32)
    return (SampleCarry(z, ep, step), cond, jnp.asarray(mask), fork)


def unpack_branch(carry: SampleCarry, groups: Sequence, width: int) -> None:
    """Scatter a packed branch-phase result back into per-group carries,
    dropping the pad rows."""
    for j, g in enumerate(groups):
        lo, n = j * width, len(g.members)
        g.carry = SampleCarry(carry.z[lo:lo + n],
                              carry.eps_prev[lo:lo + n],
                              carry.step_idx[lo])


# -- hetero row data ---------------------------------------------------------

def pack_grid(groups: Sequence, sched_T: int,
              width: Optional[int] = None) -> jnp.ndarray:
    """The DDIM grid(s) a pack bucket's rows gather timesteps from.

    Uniform step budget -> the plain 1-D grid (every row shares it; this
    is the homogeneous fast path — bit-for-bit the graph the pre-hetero
    scheduler baked into its runners).  Mixed budgets -> a 2-D (rows, L)
    stack where row j is its group's own ``ddim_timesteps`` grid,
    zero-padded to ``L = max(total_steps) + 1`` — a row's scan never
    indexes past its own ``total_steps``, so pads are never read.
    ``width`` repeats each group's grid row per member row (branch
    packs); shared packs pass ``width=None`` (one row per group).
    """
    ts = [g.total_steps for g in groups]
    if len(set(ts)) == 1:
        return jnp.asarray(ddim_timesteps(sched_T, ts[0]))
    rows = np.zeros((len(groups), max(ts) + 1), np.int64)
    for j, g in enumerate(groups):
        rows[j, :g.total_steps + 1] = ddim_timesteps(sched_T, g.total_steps)
    if width is not None:
        rows = np.repeat(rows, width, axis=0)
    return jnp.asarray(rows)


def pack_samplers(groups: Sequence, width: Optional[int] = None
                  ) -> Optional[Tuple[str, ...]]:
    """Per-row sampler assignment for a (possibly mixed-solver) bucket.

    Returns ``None`` when every group runs the same solver — the caller
    keeps the scalar-sampler path, which is both cheaper and the exact
    pre-hetero graph.  Mixed buckets get the static per-row tuple
    ``shared_phase``/``branch_phase`` dispatch on (``width`` repeats per
    member row, branch packs).
    """
    names = [g.sampler for g in groups]
    if len(set(names)) == 1:
        return None
    if width is not None:
        names = [s for s in names for _ in range(width)]
    return tuple(names)


def pad_stats(groups: Sequence, width: int) -> Tuple[int, int]:
    """(rows launched, pad rows among them) for a branch pack — the
    pad-waste numerator/denominator ``summary()`` aggregates."""
    rows = len(groups) * width
    return rows, rows - sum(len(g.members) for g in groups)
