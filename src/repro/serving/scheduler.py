"""Continuous-batching request scheduler with cross-batch trunk reuse.

``SageServingEngine.step()`` shares work only *within* one synchronous
batch: drain the queue, group once, run every group to completion.  A
production engine sees requests arrive *over time*, so this module runs
the serving loop as repeated **ticks** over in-flight groups:

* **admission** — arriving requests join an *open* group via
  ``grouping.incremental_assign`` (edge to every member, the same clique
  invariant as batch grouping) or seed a new one; WHEN an open group
  launches is delegated to a pluggable ``serving.policies.LaunchPolicy``
  — ``"eager"`` (default oracle: full / ``max_wait_ticks`` / deadline
  pressure) or ``"pad_aware"`` (holds sub-full groups inside a
  deadline-safe window and fills existing pack buckets before opening new
  ones, trading a bounded launch delay for less pad waste and fewer
  launches per tick);
* **advance** — every in-flight group moves ``slice_steps`` sampler steps
  per tick through the resumable segment API
  (``core.shared_sampling.shared_phase`` / ``branch_phase`` over an
  explicit ``SampleCarry``), jit-bucketed by (phase, segment length,
  shapes) — the start position is traced, so slices at different grid
  offsets share one compilation.  By default ticks run **packed**
  (``packed=True``): groups sharing a pack signature (phase, sampler,
  beta bucket, shape, segment length — see ``serving.packing``) are
  gathered into ONE padded super-batch and advanced by a single phase
  call with per-row step/fork indices, collapsing G per-group launches
  into one per bucket; ``packed=False`` keeps the per-group launches (the
  conformance oracle).  Packing is bitwise-invisible to results; the cost
  is pad waste on partially-filled branch rows, reported by
  ``summary()['pad_waste']`` next to ``launches_per_tick``;
* **trunk reuse** — a completed shared phase is stored in a
  :class:`~repro.serving.trunk_cache.TrunkCache`; a newly launched group
  whose centroid hits the cache skips its shared phase entirely and forks
  straight into branching (SAGE's within-batch sharing, extended across
  batches — the diffusion analogue of ``shared_prefill``'s prefix cache);
* **completion** — finished groups decode and emit
  :class:`Completed` records carrying latency and NFE accounting;
  ``summary()`` reports p50/p95 latency, NFE per request, batch occupancy
  and queue depth.

Overload resilience (the regime where arrival rate exceeds service
rate) is layered on the same tick loop:

* **QoS classes** — every request carries ``qos`` (``interactive`` |
  ``batch``); grouping never mixes classes, the advance order is the
  pluggable ``launch_order`` comparator (default ``(qos, deadline)``),
  and when ``max_groups_per_tick`` caps the tick, slots are split by
  weighted-fair queueing over the classes (``qos_weights``, deficit
  round-robin);
* **preemption** — segments are resumable, so pausing a batch group is
  free: a deadline-at-risk group claims an advance slot outright and the
  displaced batch groups simply do not advance that tick (counted in
  ``stats['preemptions']``/``'resumes'``); a ``starvation_ticks`` bound
  forces any group skipped that many consecutive ticks into the next
  tick's slots, so batch can never starve;
* **admission control / load shedding** — each arrival passes a
  ``serving.policies.AdmissionPolicy`` fed a saturation estimate
  (backlog drain ticks + arrival-rate EWMA); past saturation requests
  are shed (``status="shed"``) or degraded to draft NFE (the group runs
  at the maximum share bucket, ``status="degraded"``), and a request
  whose deadline is already unmeetable is rejected up front
  (``status="rejected_expired"``) instead of churning the launch path;
* **fault tolerance** — an optional ``serving.faults.FaultPlan`` injects
  launch failures / cache corruption / tick stalls; failed segment
  launches retry with exponential backoff (the carry is untouched, so a
  successful retry is bitwise-identical to the fault-free run) and
  exhausting ``max_retries`` sheds the group with its NFE moved to the
  ``nfe_wasted`` ledger — every fault is recovered or accounted, never a
  silent drop.

With faults off, preemption off (or no capacity cap) and a single QoS
class, all of this reduces to the PR-5 tick loop exactly — the
conformance goldens are byte-stable against it.

Heterogeneous workloads (multi-resolution / quality tiers / mixed
samplers) ride the same tick loop — each axis is per-REQUEST at
``submit()`` and per-GROUP everywhere downstream:

* **shape** — ``submit(shape=(H, W, C))`` picks any patch-divisible
  latent geometry up to the trained grid (aspect buckets included);
  groups never mix shapes, so a hetero tick launches one stacked call
  per shape bucket with per-bucket pads, and the trunk cache/telemetry
  key on the group's own shape (``summary()`` reports per-shape launch
  and pad ledgers);
* **tier** — ``submit(tier=...)`` maps to a total step budget via the
  ``tiers`` table (draft/standard/premium by default).  The budget is
  per-row DATA, not a pack axis: rows gather timesteps from their own
  group's DDIM grid (``packing.pack_grid``), so draft and premium
  groups co-pack whenever segment lengths line up.  Overload
  ``degrade`` admission is a tier downgrade onto this mechanism
  (``degrade_tier``), NOT a forced beta compartment — degraded groups
  share launches with clean traffic;
* **sampler** — ``submit(sampler=...)`` picks ddim/dpmpp per request;
  groups never mix solvers, and with ``mix_samplers=True`` packs do:
  rows dispatch per-solver inside one stacked launch
  (``shared_sampling`` row dispatch; the PackKey sampler axis collapses
  to ``"*"``).

All of it stays bitwise-invisible: the ``packed=False`` per-group loop
remains the oracle for ANY hetero mix, and a homogeneous workload runs
the exact pre-hetero graph (1-D grid, scalar sampler, full-square
positional table).

The synchronous engine is literally a special case: :meth:`run_batch`
drains one prompt list through greedy-clique grouping and phase-aligned
packed segments (ONE stacked launch per phase per tick across all beta
buckets, no arrivals, no cache), which is what
``SageServingEngine.step()`` now delegates to.

Time is injectable: every ``submit``/``tick`` takes ``now`` (any
monotonically non-decreasing float — wall seconds, or virtual tick counts
for arrival-trace simulation as in ``examples/serve_shared.py
--streaming``); it defaults to ``time.monotonic()``.

Observability (``serving.telemetry``): the stats dicts are
:class:`~repro.serving.telemetry.StatGroup` members of a
:class:`~repro.serving.telemetry.MetricsRegistry` (``summary()`` is a
view over registry-owned state; pass ``metrics=`` to share a registry
with the export path), and an optional
:class:`~repro.serving.telemetry.Tracer` receives lifecycle spans for
every request/group transition plus per-tick phase spans.  Emission is
clocked by the same injectable ``now``, so virtual-time traces are
deterministic; with ``tracer=None`` (default) every emit site is a
single ``is not None`` branch and runs are bitwise-identical to the
pre-telemetry scheduler — tracing never touches RNG or sampler inputs,
so even an *enabled* tracer is output-invisible.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from dataclasses import replace as dc_replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, SageConfig
from repro.core import grouping
from repro.core.schedule import Schedule, make_schedule
from repro.core.shared_sampling import (SampleCarry, branch_phase,
                                        branch_phase_nfe, fork_carry,
                                        group_mean, init_carry, phase_split,
                                        shared_phase, shared_phase_nfe)
from repro.models import dit, vae as vae_lib
from repro.models import text_encoder as te
from repro.serving import packing
from repro.serving.faults import FaultPlan
from repro.serving.policies import (DEGRADE, DEFAULT_QOS, DEFAULT_TIER,
                                    QOS_RANK, SHED,
                                    AdmissionContext, AdmissionPolicy,
                                    LaunchContext, LaunchPolicy,
                                    make_admission_policy, make_launch_order,
                                    make_launch_policy)
from repro.serving.telemetry import (LATENCY_BUCKETS, OCCUPANCY_BUCKETS,
                                     PID_GROUPS, PID_REQUESTS,
                                     QUEUE_DEPTH_BUCKETS, MetricsRegistry,
                                     Tracer, safe_ratio)
from repro.serving.trunk_cache import TrunkCache, TrunkEntry


@dataclass
class Completed:
    prompt: str
    image: Optional[np.ndarray]   # None when the request was not served
    group_id: int                 # -1 when refused before grouping
    nfe_share: float
    latency: float = 0.0          # completion time - arrival time
    cache_hit: bool = False       # trunk came from the cross-batch cache
    qos: str = DEFAULT_QOS
    tier: str = DEFAULT_TIER      # quality tier the request ran at
    status: str = "ok"            # ok | degraded | shed | rejected_expired


@dataclass
class Request:
    rid: int
    prompt: str
    t_arrival: float
    deadline: Optional[float]
    cond: np.ndarray              # (Lc, dc) projected text features
    pooled: np.ndarray            # (d,) pooled embedding (similarity space)
    qos: str = DEFAULT_QOS
    degraded: bool = False        # admitted at draft quality (overload)
    shape: Tuple[int, ...] = ()   # requested latent (H, W, C)
    tier: str = DEFAULT_TIER      # quality tier (total-step budget name)
    sampler: str = ""             # requested solver (ddim | dpmpp)


@dataclass
class _Group:
    """One in-flight (or open) group — always a (K=1, N) packing."""
    gid: int
    members: List[Request]
    created_tick: int
    state: str = "open"           # open | shared | branch | done
    beta: float = 0.0             # share-ratio bucket
    n_shared: int = 0
    steps_done: int = 0
    t_open: float = 0.0           # clock value when the group was seeded
    carry: Optional[SampleCarry] = None
    cbar: Any = None              # (1, Lc, dc)
    cond_flat: Any = None         # (N, Lc, dc)
    mask: Any = None              # (1, N)
    centroid: Optional[np.ndarray] = None
    cache_hit: bool = False
    nfe: float = 0.0
    t_launch: float = 0.0
    qos: str = DEFAULT_QOS        # members never mix classes
    degraded: bool = False        # any member admitted via tier downgrade
    shape: Tuple[int, ...] = ()   # latent (H, W, C) — members never mix
    tier: str = DEFAULT_TIER      # quality tier — members never mix
    sampler: str = "ddim"         # solver — members never mix
    total_steps: int = 0          # the tier's step budget (own DDIM grid)
    retries: int = 0              # consecutive failed segment launches
    next_try_tick: int = 0        # backoff gate: skip advance before this
    starved_ticks: int = 0        # consecutive ticks skipped by selection
    preempted: bool = False       # currently paused in favour of a
    #                               higher-class group (resume queue flag)

    def earliest_deadline(self) -> float:
        ds = [r.deadline for r in self.members if r.deadline is not None]
        return min(ds) if ds else float("inf")


class RequestScheduler:
    """Continuous-batching scheduler over the resumable sampling segments.

    Owns the full request path the synchronous engine used to inline:
    text-tower embedding, grouping (incremental for streaming, greedy
    cliques for :meth:`run_batch`), per-(phase, length) jitted segment
    runners, the trunk cache, VAE decode and the latency/NFE statistics.
    """

    def __init__(self, model_cfg: ModelConfig, sage: SageConfig,
                 dit_params, text_params, text_cfg, vae_params=None,
                 sched: Optional[Schedule] = None, group_size: int = 4,
                 group_max: Optional[int] = None,
                 branch_buckets: Sequence[float] = (0.2, 0.3, 0.4),
                 slice_steps: int = 4, max_wait_ticks: int = 2,
                 deadline_slack: float = 0.0,
                 trunk_cache: Optional[TrunkCache] = None,
                 max_groups_per_tick: Optional[int] = None,
                 packed: bool = True,
                 policy: Union[str, LaunchPolicy, None] = "eager",
                 launch_order: Any = "qos_edf",
                 qos_weights: Optional[Dict[str, int]] = None,
                 preempt: bool = True,
                 starvation_ticks: int = 4,
                 admission: Union[str, AdmissionPolicy, None] = None,
                 faults: Optional[FaultPlan] = None,
                 max_retries: int = 3,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tiers: Optional[Dict[str, int]] = None,
                 degrade_tier: str = "draft",
                 mix_samplers: bool = False,
                 seed: int = 0):
        """``group_size`` is the packed width N (static sampler shape);
        ``group_max`` caps clique size during batch grouping and defaults
        to N — set it larger to let ``pad_groups`` split big cliques over
        multiple packed rows.  ``packed`` gathers pack-compatible
        in-flight groups into one denoiser launch per tick (see
        ``serving.packing``); ``packed=False`` advances each group with
        its own launch — same results bitwise, G× the launches.
        ``policy`` picks the launch policy (``serving.policies``):
        ``"eager"`` (default, the PR-4 oracle) launches a group the moment
        it is full / has waited ``max_wait_ticks`` / is deadline-urgent;
        ``"pad_aware"`` holds sub-full groups up to a deadline-safe window
        and fills existing pack buckets before opening new ones (a
        :class:`~repro.serving.policies.LaunchPolicy` instance also
        works, e.g. ``PadAwarePolicy(hold_ticks=4)``).

        Overload knobs: ``launch_order`` is the advance-priority
        comparator (``"fifo"`` / ``"edf"`` / ``"qos_edf"`` default, or a
        group -> key callable); ``qos_weights`` are the WFQ weights per
        class under a ``max_groups_per_tick`` cap (default interactive 2
        : batch 1); ``preempt`` lets deadline-at-risk groups claim slots
        from lower classes (``starvation_ticks`` bounds how long any
        group can be skipped); ``admission`` is the per-request overload
        policy (``"shed"`` / ``"degrade"`` /
        :class:`~repro.serving.policies.AdmissionPolicy`); ``faults`` is
        a :class:`~repro.serving.faults.FaultPlan` for chaos testing and
        ``max_retries`` bounds per-group launch retries before the
        shed escape hatch.

        Hetero knobs: ``tiers`` maps quality-tier names to total step
        budgets (default ``draft`` = T//2, ``standard`` = T,
        ``premium`` = T + T//2, with T = ``sage.total_steps``; a
        ``"standard"`` entry is always present — it is the ``submit``
        default and the ``run_batch`` tier); ``degrade_tier`` is the
        tier overload ``degrade`` admission downgrades requests to;
        ``mix_samplers=True`` lets packs mix ddim/dpmpp rows in one
        launch (default off: one launch per solver per tick).  Latent
        shape and sampler are per-request ``submit`` arguments.

        Observability: ``tracer`` receives lifecycle/phase spans
        (``None`` disables tracing at zero cost); ``metrics`` is the
        :class:`~repro.serving.telemetry.MetricsRegistry` the stats
        groups register into (one scheduler per registry; defaults to a
        private registry, so existing call sites see no change)."""
        if group_size < 1:
            raise ValueError(f"group_size must be >= 1, got {group_size}")
        if slice_steps < 1:
            raise ValueError(f"slice_steps must be >= 1, got {slice_steps}")
        self.cfg = model_cfg
        self.sage = sage
        self.sched = sched or make_schedule(1000)
        self.dit_params = dit_params
        self.text_params = text_params
        self.text_cfg = text_cfg
        self.vae_params = vae_params
        self.group_size = group_size
        self.group_max = group_size if group_max is None else group_max
        self.branch_buckets = tuple(branch_buckets)
        self.slice_steps = slice_steps
        self.max_wait_ticks = max_wait_ticks
        self.deadline_slack = deadline_slack
        self.trunk_cache = trunk_cache
        self.max_groups_per_tick = max_groups_per_tick
        self.packed = packed
        self.policy = make_launch_policy(policy)
        self.launch_order = make_launch_order(launch_order)
        self.qos_weights = dict(qos_weights or {"interactive": 2,
                                                "batch": 1})
        for q, w in self.qos_weights.items():
            if w <= 0:
                raise ValueError(
                    f"qos_weights[{q!r}] must be > 0, got {w}")
        self.preempt = preempt
        if starvation_ticks < 1:
            raise ValueError(
                f"starvation_ticks must be >= 1, got {starvation_ticks}")
        self.starvation_ticks = starvation_ticks
        self.admission = make_admission_policy(admission)
        T = sage.total_steps
        self.tiers: Dict[str, int] = (dict(tiers) if tiers is not None
                                      else {"draft": max(1, T // 2),
                                            "standard": T,
                                            "premium": T + max(1, T // 2)})
        self.tiers.setdefault("standard", T)
        for name, steps in self.tiers.items():
            if int(steps) < 1:
                raise ValueError(
                    f"tiers[{name!r}] must be >= 1 steps, got {steps}")
            self.tiers[name] = int(steps)
        if degrade_tier not in self.tiers:
            raise ValueError(f"degrade_tier {degrade_tier!r} not in tiers "
                             f"{sorted(self.tiers)}")
        self.degrade_tier = degrade_tier
        self.mix_samplers = bool(mix_samplers)
        self.faults = faults
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.max_retries = max_retries
        self.key = jax.random.PRNGKey(seed)
        # init noise is drawn per-gid from a fixed key, NOT from a key that
        # advances per launch: a group's trajectory then depends only on
        # its identity, never on launch order or timing — which is what
        # makes launch *policies* output-invariant for equal compositions
        self._launch_key = jax.random.fold_in(self.key, 0x5A9E)

        self.arrivals: List[Request] = []      # embedded, awaiting admission
        self.open_groups: List[_Group] = []
        self.inflight: List[_Group] = []
        self.ticks = 0
        self._next_rid = 0
        self._next_gid = 0
        self._runners: Dict[Tuple, Any] = {}

        # telemetry: the stats dicts live inside a MetricsRegistry as
        # StatGroup members (plain-dict semantics, so the += hot paths
        # and every stats-reading test are untouched); the registry is
        # the single export surface for scheduler + cache + fault
        # counters, gauges and histograms.  The tracer is optional and
        # fully inert when None.
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else \
            MetricsRegistry()
        self.stats: Dict[str, float] = self.metrics.group("scheduler", {
            "nfe": 0.0, "nfe_independent": 0.0, "requests": 0,
            "completed": 0, "nfe_saved_cache": 0.0,
            # packed-execution accounting: segment launches, latent rows
            # those launches carried, and how many of the rows were pads
            "launches": 0, "pack_rows": 0, "pack_pad_rows": 0,
            # overload / robustness ledger: every refused or degraded
            # request and every injected-fault consequence is counted
            # here — conservation is requests == completed + shed +
            # shed_faulted + rejected_expired + pending
            "shed": 0, "degraded": 0, "rejected_expired": 0,
            "preemptions": 0, "resumes": 0, "retries": 0,
            "launch_faults": 0, "shed_faulted": 0, "stalled_ticks": 0,
            "deadline_met": 0, "deadline_missed": 0, "nfe_wasted": 0.0})
        # per-class mirrors of the request-outcome counters + latencies
        self.class_stats: Dict[str, Dict[str, float]] = {}
        self.class_latencies: Dict[str, "deque[float]"] = {}
        self.metrics.attach_nested("scheduler_class", self.class_stats,
                                   "qos")
        # per-tier NFE/outcome ledger and per-shape-bucket launch ledger
        # (the hetero observability: which step budget burned the NFE,
        # which geometry bucket owned the launches and the pad rows)
        self.tier_stats: Dict[str, Dict[str, float]] = {}
        self.metrics.attach_nested("scheduler_tier", self.tier_stats,
                                   "tier")
        self.shape_stats: Dict[str, Dict[str, float]] = {}
        self.metrics.attach_nested("scheduler_shape", self.shape_stats,
                                   "shape")
        self.metrics.gauge("scheduler_ticks", lambda: self.ticks)
        self.metrics.gauge("scheduler_pending", lambda: self.pending)
        self.metrics.gauge("scheduler_arrival_rate",
                           lambda: self._arrival_rate)
        self.metrics.gauge("scheduler_inflight_groups",
                           lambda: len(self.inflight))
        if faults is not None:
            self.metrics.attach_family("faults_injected",
                                       faults.injected, "kind")
            self.metrics.attach_family("faults_queries",
                                       faults.queries, "kind")
        if trunk_cache is not None:
            self.metrics.attach_group("cache", trunk_cache.stats)
            self.metrics.gauge("cache_bytes", lambda: trunk_cache.bytes)
            self.metrics.gauge("cache_entries",
                               lambda: len(trunk_cache))
            self.metrics.gauge("cache_hbm_bytes",
                               lambda: trunk_cache.tier_bytes["hbm"])
            self.metrics.gauge("cache_host_bytes",
                               lambda: trunk_cache.tier_bytes["host"])
        # fixed-bucket histograms next to the exact-percentile deques:
        # the deques keep summary()'s percentiles exact over the trailing
        # window, the histograms give the exporter cumulative
        # distributions that never reset
        self._h_latency = self.metrics.histogram(
            "scheduler_latency_ticks", LATENCY_BUCKETS)
        self._h_queue = self.metrics.histogram(
            "scheduler_queue_depth", QUEUE_DEPTH_BUCKETS)
        self._h_occupancy = self.metrics.histogram(
            "scheduler_pack_occupancy", OCCUPANCY_BUCKETS)
        # arrival-process estimate: EWMA of submitted requests per tick
        # (feeds AdmissionContext.backlog decisions and the adaptive
        # pad-aware hold budget via LaunchContext.arrival_rate)
        self._arrival_rate = 0.0
        self._arrivals_since_tick = 0
        # clock value of the tick being executed — the timestamp source
        # for trace events emitted below tick()/run_batch() in the call
        # tree (e.g. fork/store marks inside _after_segment)
        self._tick_now = 0.0
        # deficit-round-robin credit per class (persists across ticks so
        # fractional weight ratios average out over time)
        self._wfq_credit: Dict[str, float] = {}
        # bounded windows: a long-lived server must not grow stat state
        # without bound; summary() percentiles are over the trailing window
        stat_window = self._stat_window = 65_536
        self.latencies: "deque[float]" = deque(maxlen=stat_window)
        self.occupancy: "deque[float]" = deque(maxlen=stat_window)
        #                                      members/group_size at launch
        self.queue_depth: "deque[int]" = deque(maxlen=stat_window)
        #                                      waiting requests per tick

    # -- embedding ------------------------------------------------------
    def _embed(self, prompts: Sequence[str]):
        toks = te.tokenize(prompts, max_len=self.cfg.cond_len)
        feats, pooled = te.encode_text(self.text_params, self.text_cfg, toks)
        # project per-token features to the DiT cond width if needed
        if feats.shape[-1] != self.cfg.cond_dim:
            reps = -(-self.cfg.cond_dim // feats.shape[-1])
            feats = jnp.tile(feats, (1, 1, reps))[..., :self.cfg.cond_dim]
        return np.asarray(feats), np.asarray(pooled)

    @property
    def _latent_shape(self) -> Tuple[int, int, int]:
        """The DEFAULT latent geometry (full square trained grid) — the
        shape a request gets when ``submit`` is not given one.  Every
        execution site keys on the GROUP's own ``g.shape``; this property
        only seeds defaults."""
        H = self.cfg.latent_size
        return (H, H, self.cfg.latent_channels)

    def _null_cond(self):
        return jnp.zeros((self.cfg.cond_len, self.cfg.cond_dim))

    def _cfg_key(self, g: "_Group"):
        """Everything (besides the centroid/beta/shape) that must match for
        a cached trunk to be reusable — per GROUP now: the group's own
        sampler and step budget ride the key, so a draft-tier or dpmpp
        trunk can never serve a premium/ddim group.  Params are not
        hashed: the cache lives inside one scheduler, whose params are
        fixed."""
        s, c = self.sage, self.cfg
        return (c.name, c.attn_impl, g.sampler, s.step_impl, g.total_steps,
                round(s.guidance_scale, 6), round(s.clip_x0, 6),
                s.shared_uncond_cfg, self.sched.T)

    # -- jit-bucketed segment runners -----------------------------------
    def _eps_fn(self):
        params, cfg = self.dit_params, self.cfg
        return lambda z, t, c: dit.forward(params, cfg, z, t, c)

    def _runner_cfg(self, samplers):
        """Resolve a runner's sampler spec: a solver NAME (uniform pack —
        the scalar path, graph-identical to pre-hetero) or a per-row
        tuple (mixed pack — ``row_samplers`` dispatch)."""
        if isinstance(samplers, str):
            return dc_replace(self.sage, sampler=samplers), None
        return self.sage, tuple(samplers)

    def _shared_runner(self, n_steps: int, samplers):
        key = ("shared", n_steps, samplers)
        if key not in self._runners:
            eps_fn, sched = self._eps_fn(), self.sched
            sage, rs = self._runner_cfg(samplers)

            @jax.jit
            def run(carry, cbar, null, grid):
                return shared_phase(eps_fn, sched, sage, carry, cbar, null,
                                    n_steps, grid=grid, row_samplers=rs)
            self._runners[key] = run
        return self._runners[key]

    def _branch_runner(self, n_steps: int, samplers):
        key = ("branch", n_steps, samplers)
        if key not in self._runners:
            eps_fn, sched = self._eps_fn(), self.sched
            sage, rs = self._runner_cfg(samplers)

            @jax.jit
            def run(carry, cond_flat, mask, null, fork_idx, grid):
                return branch_phase(eps_fn, sched, sage, carry, cond_flat,
                                    mask, null, n_steps, fork_idx,
                                    grid=grid, row_samplers=rs)
            self._runners[key] = run
        return self._runners[key]

    # -- submission & admission -----------------------------------------
    @staticmethod
    def _now(now: Optional[float]) -> float:
        return time.monotonic() if now is None else float(now)

    def _check_shape(self, shape) -> Tuple[int, int, int]:
        """Validate a requested latent geometry: 3-tuple, the model's
        channel count, patch-divisible spatial dims within the trained
        positional grid (the DiT windows its pos table down — it cannot
        extrapolate up)."""
        shp = tuple(int(x) for x in shape)
        if len(shp) != 3:
            raise ValueError(f"shape must be (H, W, C), got {shape!r}")
        H, W, C = shp
        if C != self.cfg.latent_channels:
            raise ValueError(f"shape channels {C} != model latent_channels "
                             f"{self.cfg.latent_channels}")
        p, top = self.cfg.patch, self.cfg.latent_size
        if H < 1 or W < 1 or H % p or W % p:
            raise ValueError(f"shape ({H},{W}) must be positive multiples "
                             f"of patch {p}")
        if H > top or W > top:
            raise ValueError(f"shape ({H},{W}) exceeds the trained grid "
                             f"{top}x{top}")
        return shp

    @staticmethod
    def _per_request(val, default, n: int, name: str) -> List:
        """Broadcast a scalar-for-batch submit argument or validate a
        per-prompt sequence of length n."""
        if val is None:
            return [default] * n
        if isinstance(val, str) or (isinstance(val, tuple)
                                    and val and not isinstance(val[0],
                                                               (tuple, list))):
            return [val] * n
        vals = list(val)
        if len(vals) != n:
            raise ValueError(f"{name} sequence length {len(vals)} != "
                             f"{n} prompts")
        return vals

    def submit(self, prompts: Sequence[str], now: Optional[float] = None,
               deadline: Optional[float] = None,
               qos: Union[str, Sequence[str]] = DEFAULT_QOS,
               shape=None, tier=None, sampler=None) -> List[int]:
        """Queue prompts (one text-tower call per submit batch); they are
        grouped at the next tick.  ``qos`` is one class for the whole
        batch or a per-prompt sequence (``"interactive"`` | ``"batch"``).
        ``shape`` / ``tier`` / ``sampler`` are the hetero axes — each one
        value for the whole batch or a per-prompt sequence: ``shape`` a
        patch-divisible (H, W, C) up to the trained grid (default the
        full square), ``tier`` a ``tiers`` name mapping to the total step
        budget (default ``"standard"``), ``sampler`` ``"ddim"`` |
        ``"dpmpp"`` (default ``sage.sampler``).  Requests only group with
        compartment-mates (same qos AND shape AND tier AND sampler).
        Returns request ids."""
        if not prompts:
            return []
        now = self._now(now)
        n = len(prompts)
        qs = self._per_request(qos, DEFAULT_QOS, n, "qos")
        for q in qs:
            if q not in QOS_RANK:
                raise ValueError(f"unknown qos class {q!r}; "
                                 f"have {sorted(QOS_RANK)}")
        shapes = [self._check_shape(s) for s in self._per_request(
            tuple(shape) if isinstance(shape, (tuple, list)) else shape,
            self._latent_shape, n, "shape")]
        tiers = self._per_request(tier, DEFAULT_TIER, n, "tier")
        for t in tiers:
            if t not in self.tiers:
                raise ValueError(f"unknown tier {t!r}; "
                                 f"have {sorted(self.tiers)}")
        samplers = self._per_request(sampler, self.sage.sampler, n,
                                     "sampler")
        for s in samplers:
            if s not in ("ddim", "dpmpp"):
                raise ValueError(f"unknown sampler {s!r}; "
                                 f"have ['ddim', 'dpmpp']")
        conds, pooled = self._embed(prompts)
        rids = []
        tr = self.tracer
        for p, c, e, q, shp, t, smp in zip(prompts, conds, pooled, qs,
                                           shapes, tiers, samplers):
            r = Request(self._next_rid, p, now, deadline, c, e, qos=q,
                        shape=shp, tier=t, sampler=smp)
            self._next_rid += 1
            self.arrivals.append(r)
            rids.append(r.rid)
            if tr is not None:
                tr.instant("request.submit", now, pid=PID_REQUESTS,
                           tid=r.rid, qos=q, deadline=deadline,
                           shape="x".join(map(str, shp)), tier=t,
                           sampler=smp)
        self.stats["requests"] += len(prompts)
        self._arrivals_since_tick += len(prompts)
        return rids

    # -- overload accounting ---------------------------------------------
    def _cstat(self, qos: str, key: str, inc: float = 1) -> None:
        d = self.class_stats.setdefault(
            qos, {"requests": 0, "completed": 0, "shed": 0, "degraded": 0,
                  "rejected_expired": 0, "preemptions": 0,
                  "deadline_met": 0, "deadline_missed": 0})
        d[key] = d.get(key, 0) + inc

    def _tstat(self, tier: str, key: str, inc: float = 1) -> None:
        d = self.tier_stats.setdefault(
            tier, {"requests": 0, "completed": 0, "nfe": 0.0})
        d[key] = d.get(key, 0) + inc

    def _refuse(self, r: Request, status: str,
                now: float = 0.0) -> Completed:
        """An accounted non-service outcome (shed / rejected_expired):
        the request leaves the system as a Completed record with no
        image — conservation still sees it exactly once."""
        self.stats[status] += 1
        self._cstat(r.qos, "requests")
        self._cstat(r.qos, status)
        self._tstat(r.tier, "requests")
        if self.tracer is not None:
            self.tracer.instant(f"request.{status}", now,
                                pid=PID_REQUESTS, tid=r.rid, qos=r.qos)
        return Completed(prompt=r.prompt, image=None, group_id=-1,
                         nfe_share=0.0, latency=0.0, qos=r.qos,
                         tier=r.tier, status=status)

    def _remaining_ticks(self, g: _Group) -> int:
        """Conservative advance-ticks left for an in-flight group: one
        segment per tick plus one for the shared->branch boundary (the
        group's own tier budget, not the deployment default)."""
        rem = g.total_steps - g.steps_done
        return -(-rem // self.slice_steps) + (1 if g.state == "shared"
                                              else 0)

    def _backlog_ticks(self) -> float:
        """Saturation estimate: ticks to drain the work already in the
        system.  Under a ``max_groups_per_tick`` cap the advance slots
        are the bottleneck (sum of per-group ticks over the cap);
        uncapped, every group advances each tick and the backlog is just
        the longest remaining group."""
        ttf = self._ticks_to_finish()
        loads = [self._remaining_ticks(g) for g in self.inflight]
        loads += [self._ticks_to_finish(g.total_steps)
                  for g in self.open_groups]
        if not loads:
            return 0.0
        if self.max_groups_per_tick is None:
            return float(max(loads))
        return sum(loads) / self.max_groups_per_tick

    def _admit(self, now: float) -> List[Completed]:
        """Admission: expired-deadline rejection and the overload policy
        first, then class-compartmented incremental grouping (a request
        only joins an open group of its own (qos, tier, shape, sampler)
        compartment — mixing qos would let a batch member drag an
        interactive group; mixing tiers/shapes/samplers inside a *group*
        is impossible because members share one trunk).  A DEGRADE
        verdict is a tier downgrade (to ``degrade_tier``): the request
        then groups — and packs — with native requests of that tier.
        Returns the refusal records for this tick."""
        notices: List[Completed] = []
        if not self.arrivals:
            return notices
        backlog = self._backlog_ticks()
        ttf = self._ticks_to_finish()
        per_group = (ttf / self.max_groups_per_tick
                     if self.max_groups_per_tick else 0.0)
        arrivals, self.arrivals = self.arrivals, []
        # member-embedding stacks maintained incrementally: only the group
        # an arrival joins changes, so a burst of A arrivals over G open
        # groups costs O(A + G) stacks, not O(A * G)
        open_embeds = [np.stack([m.pooled for m in g.members])
                       for g in self.open_groups]
        tr = self.tracer
        for r in arrivals:
            # bugfix (was: churn through the normal launch path): a
            # deadline already expired — or expiring within one segment,
            # so even an immediate solo launch cannot finish in time —
            # is refused up front with its own status
            if r.deadline is not None and r.deadline <= now + 1.0:
                notices.append(self._refuse(r, "rejected_expired", now))
                continue
            verdict = self.admission.decide(AdmissionContext(
                now=now, qos=r.qos, deadline=r.deadline,
                backlog_ticks=backlog, ticks_to_finish=ttf,
                arrival_rate=self._arrival_rate))
            if verdict == SHED:
                notices.append(self._refuse(r, "shed", now))
                continue
            if verdict == DEGRADE:
                r.degraded = True
                r.tier = self.degrade_tier
            self._cstat(r.qos, "requests")
            self._tstat(r.tier, "requests")
            if tr is not None:
                tr.instant("request.admit", now, pid=PID_REQUESTS,
                           tid=r.rid, qos=r.qos, degraded=r.degraded,
                           tier=r.tier)
            cand = [i for i, g in enumerate(self.open_groups)
                    if g.qos == r.qos and g.tier == r.tier
                    and g.shape == r.shape and g.sampler == r.sampler]
            gi = grouping.incremental_assign(
                r.pooled, [open_embeds[i] for i in cand],
                self.sage.tau_min, group_max=self.group_size)
            if gi >= 0:
                i = cand[gi]
                self.open_groups[i].members.append(r)
                self.open_groups[i].degraded = (
                    self.open_groups[i].degraded or r.degraded)
                open_embeds[i] = np.concatenate(
                    [open_embeds[i], r.pooled[None]], 0)
                gid, seeded = self.open_groups[i].gid, False
            else:
                self.open_groups.append(
                    _Group(self._next_gid, [r], created_tick=self.ticks,
                           t_open=now, qos=r.qos, degraded=r.degraded,
                           shape=r.shape, tier=r.tier, sampler=r.sampler,
                           total_steps=self.tiers[r.tier]))
                self._next_gid += 1
                open_embeds.append(np.asarray(r.pooled)[None])
                backlog += per_group     # each seeded group deepens the
                #                          queue the next verdict sees
                gid, seeded = self.open_groups[-1].gid, True
            if tr is not None:
                tr.instant("request.group", now, pid=PID_REQUESTS,
                           tid=r.rid, gid=gid, seeded=seeded)
        return notices

    # -- launch ----------------------------------------------------------
    @staticmethod
    def _min_sim(sim_sub: np.ndarray) -> float:
        """Group tightness = min pairwise similarity of a square sim
        submatrix; singletons pin to 1.0 (they share with nobody, so the
        bucket choice only affects their own — cost-neutral — split)."""
        if sim_sub.shape[0] == 1:
            return 1.0
        iu = np.triu_indices(sim_sub.shape[0], k=1)
        return float(sim_sub[iu].min())

    def _beta_bucket(self, min_sim: float, adaptive: bool) -> float:
        """THE share-ratio bucket rule (used by both the streaming launch
        path and ``run_batch`` — one copy, so the trunk-cache
        ``beta_bucket`` key can never diverge between them): tighter
        groups share more, min_sim in [0, 1] -> beta_raw in [0, 0.5],
        snapped to the nearest branch bucket."""
        if not adaptive:
            return self.sage.share_ratio
        beta_raw = float(np.clip(min_sim, 0.0, 1.0)) * 0.5
        return min(self.branch_buckets, key=lambda b: abs(b - beta_raw))

    def _group_beta(self, members: List[Request], adaptive: bool) -> float:
        """Per-group share-ratio bucket (singletons only drag *their own*
        bucket — the old batch-mean bug is gone)."""
        e = np.stack([m.pooled for m in members])
        return self._beta_bucket(
            self._min_sim(grouping.similarity_matrix(e)), adaptive)

    def _effective_beta(self, g: _Group, adaptive: bool) -> float:
        """The bucket a group actually runs at — the similarity rule,
        nothing else.  Degraded admission used to force the maximum
        share bucket here, which pushed degraded groups into their own
        pack compartment (distinct phase boundaries) even though beta is
        not a pack axis; the NFE saving now comes from the *tier* step
        budget instead, so degraded groups co-pack with native ones."""
        return self._group_beta(g.members, adaptive)

    def _launch(self, g: _Group, now: float, adaptive: bool,
                beta: Optional[float] = None) -> None:
        T = g.total_steps
        g.beta = self._effective_beta(g, adaptive) if beta is None \
            else beta
        g.n_shared, _ = phase_split(T, g.beta)
        N = len(g.members)
        cond = jnp.asarray(np.stack([m.cond for m in g.members]))
        g.cond_flat = cond                              # (N, Lc, dc)
        g.mask = jnp.ones((1, N))
        g.cbar = group_mean(cond[None], g.mask)         # (1, Lc, dc)
        g.centroid = np.mean(np.stack([m.pooled for m in g.members]), 0)
        g.t_launch = now
        self.occupancy.append(N / self.group_size)
        self._h_occupancy.observe(N / self.group_size)
        self.stats["nfe_independent"] += 2.0 * N * T
        tr = self.tracer
        if tr is not None:
            # hold span: the open-group dwell from seed to launch (what
            # a launch policy trades against pad waste)
            tr.span("group.hold", g.t_open, now - g.t_open,
                    pid=PID_GROUPS, tid=g.gid, qos=g.qos,
                    waited_ticks=self.ticks - g.created_tick)

        entry = None
        if self.trunk_cache is not None and g.n_shared > 0:
            cs = self.trunk_cache.stats
            pre = (cs["exact_hits"], cs["hits_host"])
            entry = self.trunk_cache.lookup(
                g.centroid, g.beta, self._cfg_key(g), g.shape,
                payload="trunk")
            if tr is not None:
                # classify the lookup from the cache's own counters
                # (exact-key vs ANN/similarity vs miss, and which tier
                # served it) — the cache API stays untouched
                if entry is None:
                    tr.instant("cache.miss", now, pid=PID_GROUPS,
                               tid=g.gid)
                else:
                    kind = ("cache.exact" if cs["exact_hits"] > pre[0]
                            else "cache.ann")
                    tier = ("host" if cs["hits_host"] > pre[1]
                            else "hbm")
                    tr.instant(kind, now, pid=PID_GROUPS, tid=g.gid,
                               tier=tier)
        if entry is not None:
            # cross-batch trunk hit: skip the shared phase entirely, fork
            # straight into branching from the cached branch-point latent.
            trunk = SampleCarry(jnp.asarray(entry.z),
                                jnp.zeros_like(jnp.asarray(entry.z)),
                                jnp.int32(entry.step_idx))
            g.carry = fork_carry(trunk, N)
            g.steps_done = g.n_shared
            g.state = "branch"
            g.cache_hit = True
            self.stats["nfe_saved_cache"] += shared_phase_nfe(1, g.n_shared)
        else:
            rng = jax.random.fold_in(self._launch_key, g.gid)
            g.carry = init_carry(rng, 1, g.shape)
            if g.n_shared == 0:
                g.carry = fork_carry(g.carry, N)
                g.state = "branch"
            else:
                g.state = "shared"
        if tr is not None:
            tr.instant("group.launch", now, pid=PID_GROUPS, tid=g.gid,
                       n=N, beta=g.beta, n_shared=g.n_shared, qos=g.qos,
                       cache_hit=g.cache_hit, state=g.state)
        self.open_groups.remove(g)
        self.inflight.append(g)

    # -- advance ---------------------------------------------------------
    def _store_trunk(self, g: _Group) -> None:
        if self.trunk_cache is None:
            return
        stored = self.trunk_cache.insert(TrunkEntry(
            z=g.carry.z, eps_prev=g.carry.eps_prev, step_idx=g.n_shared,
            beta_bucket=g.beta, rng_fold=g.gid, centroid=g.centroid,
            cfg_key=self._cfg_key(g), payload="trunk"),
            shape=g.shape)
        if self.tracer is not None:
            self.tracer.instant("cache.store", self._tick_now,
                                pid=PID_GROUPS, tid=g.gid,
                                stored=bool(stored))

    def _count_launch(self, rows: int, pad_rows: int,
                      phase: str = "", n_steps: int = 0,
                      groups: int = 1, shape=None) -> None:
        """THE segment-launch choke point: every denoiser dispatch —
        packed bucket or per-group — lands here exactly once, so the
        stats ledger and the trace's ``phase.*`` launch spans can never
        disagree (the reconciliation test pins spans == launches)."""
        self.stats["launches"] += 1
        self.stats["pack_rows"] += rows
        self.stats["pack_pad_rows"] += pad_rows
        skey = "x".join(map(str, shape)) if shape else None
        if skey is not None:
            d = self.shape_stats.setdefault(
                skey, {"launches": 0, "rows": 0, "pad_rows": 0})
            d["launches"] += 1
            d["rows"] += rows
            d["pad_rows"] += pad_rows
        if self.tracer is not None and phase:
            kw = {"shape": skey} if skey is not None else {}
            self.tracer.launch_span(f"phase.{phase}", rows=rows,
                                    pad_rows=pad_rows, n_steps=n_steps,
                                    groups=groups, **kw)

    def _after_segment(self, g: _Group, s: int) -> None:
        """Post-advance accounting + phase transitions, shared by the
        packed and per-group paths (NFE counts the *logical* per-group
        evals — pad rows are real compute but ride the pad-waste stat,
        keeping NFE comparable between modes and with the sync engine)."""
        g.steps_done += s
        if g.state == "shared":
            g.nfe += shared_phase_nfe(1, s)
            if g.steps_done == g.n_shared:
                self._store_trunk(g)
                g.carry = fork_carry(g.carry, len(g.members))
                g.state = "branch"
                if self.tracer is not None:
                    self.tracer.instant("group.fork", self._tick_now,
                                        pid=PID_GROUPS, tid=g.gid,
                                        step_idx=g.n_shared)
        else:
            g.nfe += float(branch_phase_nfe(g.mask, s,
                                            self.sage.shared_uncond_cfg))
            if g.steps_done == g.total_steps:
                g.state = "done"

    def _advance(self, g: _Group) -> bool:
        """One segment of at most ``slice_steps`` for ONE group — the
        ``packed=False`` oracle path (one launch per group per tick).
        Returns whether the launch succeeded; an injected failure leaves
        the carry untouched (the retry re-runs the same computation)."""
        if self.faults is not None and self.faults.launch_fails():
            self.stats["launch_faults"] += 1
            return False
        null = self._null_cond()
        grid = packing.pack_grid([g], self.sched.T)
        if g.state == "shared":
            s = min(self.slice_steps, g.n_shared - g.steps_done)
            g.carry = self._shared_runner(s, g.sampler)(
                g.carry, g.cbar, null, grid)
            self._count_launch(1, 0, phase="shared", n_steps=s,
                               shape=g.shape)
        else:
            s = min(self.slice_steps, g.total_steps - g.steps_done)
            g.carry = self._branch_runner(s, g.sampler)(
                g.carry, g.cond_flat, g.mask, null, jnp.int32(g.n_shared),
                grid)
            self._count_launch(len(g.members), 0, phase="branch",
                               n_steps=s, shape=g.shape)
        self._after_segment(g, s)
        g.retries = 0
        return True

    def _advance_packed(self, todo: List[_Group],
                        slice_steps: Optional[int] = None,
                        align_phases: bool = False) -> List[_Group]:
        """One tick of packed execution: bucket the in-flight groups by
        pack signature, advance each bucket with ONE phase call over a
        stacked carry (per-row step/fork indices), scatter back.  Buckets
        are built from pre-tick states, so a group forking shared->branch
        this tick joins branch packs only from the next tick — exactly
        the per-group ordering.  Transitions (trunk-cache stores, forks,
        completions) run AFTER all buckets, in ``todo`` order, so the
        cache's insert/LRU-recency order is identical to per-group mode
        even when a byte budget forces evictions.

        ``align_phases=True`` (the ``run_batch`` drain) aligns segment
        lengths within each phase so every tick issues at most one
        stacked launch per phase — see ``packing.build_packs``.

        Returns the groups whose bucket's launch was failed by the fault
        plan this tick (their carries are untouched; ``tick()`` routes
        them through the retry/shed machinery).  Fault injection is per
        *launch*, so one failed bucket takes all its pack-mates down
        together — exactly the blast radius of a real failed dispatch."""
        null = self._null_cond()
        seg_len: Dict[int, int] = {}
        failed: List[_Group] = []
        for key, groups in packing.build_packs(
                todo, self.slice_steps if slice_steps is None else
                slice_steps, mix_samplers=self.mix_samplers,
                align_phases=align_phases, order_key=self.launch_order):
            s = key.n_steps
            if self.faults is not None and self.faults.launch_fails():
                self.stats["launch_faults"] += 1
                if self.tracer is not None:
                    self.tracer.exec_mark(
                        "launch.fault", phase=key.phase,
                        groups=len(groups))
                failed.extend(groups)
                continue
            if key.phase == "shared":
                carry, cbar = packing.pack_shared(groups)
                rs = packing.pack_samplers(groups)
                samplers = rs if rs is not None else groups[0].sampler
                grid = packing.pack_grid(groups, self.sched.T)
                out = self._shared_runner(s, samplers)(carry, cbar, null,
                                                       grid)
                packing.unpack_shared(out, groups)
                self._count_launch(len(groups), 0, phase="shared",
                                   n_steps=s, groups=len(groups),
                                   shape=key.shape)
            else:
                carry, cond, mask, fork = packing.pack_branch(
                    groups, self.group_size)
                rs = packing.pack_samplers(groups, self.group_size)
                samplers = rs if rs is not None else groups[0].sampler
                grid = packing.pack_grid(groups, self.sched.T,
                                         self.group_size)
                out = self._branch_runner(s, samplers)(carry, cond, mask,
                                                       null, fork, grid)
                packing.unpack_branch(out, groups, self.group_size)
                rows, pads = packing.pad_stats(groups, self.group_size)
                self._count_launch(rows, pads, phase="branch",
                                   n_steps=s, groups=len(groups),
                                   shape=key.shape)
            for g in groups:
                seg_len[g.gid] = s
        for g in todo:
            if g.gid in seg_len:
                self._after_segment(g, seg_len[g.gid])
                g.retries = 0
        return failed

    def _handle_failures(self, failed: List[_Group],
                         now: float) -> List[Completed]:
        """Retry-with-backoff, bounded by ``max_retries``: a failed group
        keeps its carry and is re-advanced after ``2^(retries-1)`` ticks
        (capped at 8) — a successful retry is bitwise-identical to the
        fault-free run.  Exhaustion takes the shed escape hatch: members
        complete with ``status='shed'`` and the NFE already spent moves
        to the ``nfe_wasted`` ledger (never a silent drop)."""
        out: List[Completed] = []
        tr = self.tracer
        for g in failed:
            g.retries += 1
            if g.retries <= self.max_retries:
                self.stats["retries"] += 1
                g.next_try_tick = self.ticks + min(2 ** (g.retries - 1), 8)
                if tr is not None:
                    tr.instant("group.retry", now, pid=PID_GROUPS,
                               tid=g.gid, attempt=g.retries,
                               next_try_tick=g.next_try_tick)
                continue
            self.inflight.remove(g)
            self.stats["shed_faulted"] += len(g.members)
            self.stats["nfe_wasted"] += g.nfe
            for r in g.members:
                self._cstat(r.qos, "shed")
                if tr is not None:
                    tr.instant("request.shed_faulted", now,
                               pid=PID_REQUESTS, tid=r.rid, gid=g.gid,
                               qos=r.qos)
                out.append(Completed(
                    prompt=r.prompt, image=None, group_id=g.gid,
                    nfe_share=0.0, latency=now - r.t_arrival, qos=r.qos,
                    tier=r.tier, status="shed"))
        return out

    def _decode(self, latents: jnp.ndarray) -> np.ndarray:
        """latents (B, H, W, C) -> images (or raw latents without a VAE)."""
        if self.vae_params is not None:
            return np.asarray(vae_lib.decode(self.vae_params, latents))
        return np.asarray(latents)

    def _complete(self, g: _Group, now: float,
                  record_latency: bool = True) -> List[Completed]:
        imgs = self._decode(g.carry.z)
        self.stats["nfe"] += g.nfe
        self.stats["completed"] += len(g.members)
        tr = self.tracer
        done = []
        for i, r in enumerate(g.members):
            # per-REQUEST status: a degraded (tier-downgraded) request
            # may co-group with native draft-tier traffic, which stays
            # plain "ok" — degradation is an admission outcome, not a
            # property of the group it happened to land in
            status = "degraded" if r.degraded else "ok"
            lat = now - r.t_arrival if record_latency else 0.0
            if tr is not None:
                tr.span("request.complete", r.t_arrival, lat,
                        pid=PID_REQUESTS, tid=r.rid, gid=g.gid,
                        qos=r.qos, status=status, tier=r.tier,
                        cache_hit=g.cache_hit)
            if record_latency:
                self._h_latency.observe(lat)
                # per-class outcome ledger (goodput = deadline-met
                # completions; deadline-free requests always count as met)
                self.latencies.append(lat)
                self.class_latencies.setdefault(
                    r.qos, deque(maxlen=self._stat_window)).append(lat)
                self._cstat(r.qos, "completed")
                self._tstat(r.tier, "completed")
                self._tstat(r.tier, "nfe", g.nfe / len(g.members))
                if r.degraded:
                    self.stats["degraded"] += 1
                    self._cstat(r.qos, "degraded")
                met = r.deadline is None or now <= r.deadline
                key = "deadline_met" if met else "deadline_missed"
                self.stats[key] += 1
                self._cstat(r.qos, key)
            done.append(Completed(
                prompt=r.prompt, image=imgs[i], group_id=g.gid,
                nfe_share=g.nfe / len(g.members), latency=lat,
                cache_hit=g.cache_hit, qos=r.qos, tier=r.tier,
                status=status))
        return done

    # -- launch-policy context -------------------------------------------
    def _ticks_to_finish(self, total_steps: Optional[int] = None) -> int:
        """Conservative ticks a freshly launched group needs to complete:
        one segment per tick, plus one for the shared->branch boundary.
        ``total_steps`` defaults to the deployment (standard-tier) budget;
        pass a group's own tier budget for per-group estimates."""
        t = self.sage.total_steps if total_steps is None else total_steps
        return -(-t // self.slice_steps) + 1

    def _open_signature(self, g: _Group, adaptive: bool) -> packing.PackKey:
        """The pack bucket an OPEN group would occupy if launched this
        tick (``policies.LaunchContext.signature_of``)."""
        n_shared, _ = phase_split(g.total_steps,
                                  self._effective_beta(g, adaptive))
        limit = n_shared if n_shared > 0 else g.total_steps
        return packing.PackKey(
            "shared" if n_shared > 0 else "branch",
            packing.MIXED if self.mix_samplers else g.sampler,
            tuple(g.shape), min(self.slice_steps, limit))

    def _launch_context(self, now: float, adaptive: bool) -> LaunchContext:
        ttf = max([self._ticks_to_finish()]
                  + [self._ticks_to_finish(g.total_steps)
                     for g in self.open_groups])
        return LaunchContext(
            now=now, tick=self.ticks, group_size=self.group_size,
            max_wait_ticks=self.max_wait_ticks,
            deadline_slack=self.deadline_slack,
            ticks_to_finish=ttf,
            inflight_signatures=frozenset(
                packing.pack_signature(g, self.slice_steps,
                                       self.mix_samplers)
                for g in self.inflight),
            signature_of=lambda g: self._open_signature(g, adaptive),
            arrival_rate=self._arrival_rate)

    # -- advance-slot selection ------------------------------------------
    def _at_risk(self, g: _Group, now: float) -> bool:
        """Deadline-at-risk test: skipping even one tick (one time unit
        under the virtual clock) would push the group's conservative
        finish past its earliest deadline (plus the configured slack)."""
        dl = g.earliest_deadline()
        if dl == float("inf"):
            return False
        return dl - now <= (self._remaining_ticks(g)
                            + self.deadline_slack + 1.0)

    def _preemptive_select(self, ready: List[_Group], cap: int,
                           now: float) -> List[_Group]:
        """Claim the capped advance slots in three passes over the
        ``launch_order``-sorted ready list: any group at the
        ``starvation_ticks`` bound is forced in first (the bound is a
        hard guarantee — it must hold even when every tick brings fresh
        at-risk work, so it outranks the deadline pass), then
        deadline-at-risk groups take slots outright (this is the
        preemption — displaced groups simply do not advance, their
        carries parked until resumed), then the remaining slots go by
        deficit round-robin over the QoS classes with ``qos_weights``
        (credit persists across ticks, so fractional weight ratios are
        honoured in the long run)."""
        slots: List[_Group] = []
        taken = set()

        def take(g: _Group) -> None:
            slots.append(g)
            taken.add(g.gid)

        # pass 1: the no-starvation bound — longest-starved first (NOT
        # launch order: under deep backlog many groups sit at the bound,
        # and scanning by class would let starving interactive groups
        # shut out a longer-starved batch group indefinitely)
        starving = sorted(
            (g for g in ready
             if g.starved_ticks >= self.starvation_ticks),
            key=lambda g: (-g.starved_ticks,) + tuple(self.launch_order(g)))
        for g in starving:
            if len(slots) >= cap:
                break
            take(g)
        for g in ready:              # pass 2: deadline-at-risk claim
            if len(slots) >= cap:
                break
            if g.gid not in taken and self._at_risk(g, now):
                take(g)
        if len(slots) < cap:         # pass 3: weighted-fair round-robin
            queues: Dict[str, "deque[_Group]"] = {}
            for g in ready:
                if g.gid not in taken:
                    queues.setdefault(g.qos, deque()).append(g)
            classes = sorted(queues,
                             key=lambda q: (QOS_RANK.get(q, len(QOS_RANK)),
                                            q))
            while len(slots) < cap and any(queues.values()):
                for q in classes:
                    if not queues[q]:
                        self._wfq_credit[q] = 0.0   # no deficit hoarding
                        continue
                    self._wfq_credit[q] = (self._wfq_credit.get(q, 0.0)
                                           + self.qos_weights.get(q, 1))
                    while (queues[q] and len(slots) < cap
                           and self._wfq_credit[q] >= 1.0):
                        take(queues[q].popleft())
                        self._wfq_credit[q] -= 1.0
        # preemption accounting: anyone the plain priority prefix would
        # have advanced this tick but the claiming passes displaced
        for g in ready[:cap]:
            if g.gid not in taken and not g.preempted:
                g.preempted = True
                self.stats["preemptions"] += 1
                self._cstat(g.qos, "preemptions")
                if self.tracer is not None:
                    self.tracer.instant("group.preempt", now,
                                        pid=PID_GROUPS, tid=g.gid,
                                        qos=g.qos)
        return slots

    def _select_todo(self, now: float) -> List[_Group]:
        """This tick's advance set.  Uncapped, every launch-ready group
        advances (retry backoff is the only filter).  Under a
        ``max_groups_per_tick`` cap, ``preempt=False`` gives the slots to
        the plain ``launch_order`` prefix (the PR-5 rule under the
        default single-class order); ``preempt=True`` routes them through
        :meth:`_preemptive_select`.  Starvation/resume bookkeeping lives
        here so both paths age skipped groups consistently."""
        ready = [g for g in self.inflight if g.next_try_tick <= self.ticks]
        ready.sort(key=self.launch_order)
        cap = self.max_groups_per_tick
        if cap is None or len(ready) <= cap:
            selected = ready
        elif not self.preempt:
            selected = ready[:cap]
        else:
            selected = self._preemptive_select(ready, cap, now)
        chosen = {g.gid for g in selected}
        for g in ready:
            if g.gid in chosen:
                if g.preempted:
                    g.preempted = False
                    self.stats["resumes"] += 1
                    if self.tracer is not None:
                        self.tracer.instant("group.resume", now,
                                            pid=PID_GROUPS, tid=g.gid,
                                            qos=g.qos)
                g.starved_ticks = 0
            else:
                g.starved_ticks += 1
        return selected

    # -- the tick --------------------------------------------------------
    def tick(self, now: Optional[float] = None,
             adaptive: Optional[bool] = None) -> List[Completed]:
        """One engine iteration: admit arrivals (returning shed /
        rejected notices alongside completions), launch the groups the
        launch policy selects, advance the selected in-flight groups one
        segment each, emit completions."""
        now = self._now(now)
        adaptive = (self.sage.adaptive_branch if adaptive is None
                    else adaptive)
        self.ticks += 1
        self._tick_now = now
        tr = self.tracer
        if tr is not None:
            tr.tick_begin(now, self.ticks)
        # arrival-process EWMA (requests per tick) — feeds admission
        # decisions and the adaptive pad-aware hold budget
        self._arrival_rate = (0.5 * self._arrivals_since_tick
                              + 0.5 * self._arrival_rate)
        self._arrivals_since_tick = 0
        if self.faults is not None and self.faults.tick_stalls():
            # a stalled tick is pure lost time: no admission, no
            # launches, no segments.  Deadline machinery sees the lost
            # time on the next live tick — stalled-away slack surfaces
            # as at-risk claims or rejected_expired, never silently
            self.stats["stalled_ticks"] += 1
            if tr is not None:
                tr.exec_mark("tick.stall")
                tr.tick_end(stalled=True)
            return []
        if tr is not None:
            tr.phase_begin("admit")
        done: List[Completed] = self._admit(now)
        depth = sum(len(g.members) for g in self.open_groups)
        self.queue_depth.append(depth)
        self._h_queue.observe(depth)

        if tr is not None:
            tr.phase_begin("launch")
        ctx = self._launch_context(now, adaptive)
        for g in self.policy.launches(list(self.open_groups), ctx):
            self._launch(g, now, adaptive)

        if tr is not None:
            tr.phase_begin("advance")
        todo = self._select_todo(now)
        failed: List[_Group] = []
        if self.packed:
            if todo:
                failed = self._advance_packed(todo)
        else:
            for g in todo:
                if not self._advance(g):
                    failed.append(g)
        if tr is not None:
            tr.phase_begin("complete")
        done.extend(self._handle_failures(failed, now))
        for g in todo:
            if g.state == "done":
                done.extend(self._complete(g, now))
                self.inflight.remove(g)
        if tr is not None:
            tr.tick_end(completions=len(done))
        return done

    def drain(self, now: Optional[float] = None,
              max_ticks: int = 10_000) -> List[Completed]:
        """Tick until no work remains.  ``now`` is passed to every tick:
        provide it when driving a virtual clock (the clock then stands
        still for the whole drain); omit it only under the wall-clock
        default — mixing virtual-time submits with a wall-clock drain
        would corrupt the latency stats."""
        done: List[Completed] = []
        for _ in range(max_ticks):
            if not (self.arrivals or self.open_groups or self.inflight):
                break
            done.extend(self.tick(now))
        return done

    @property
    def pending(self) -> int:
        return (len(self.arrivals)
                + sum(len(g.members) for g in self.open_groups)
                + sum(len(g.members) for g in self.inflight))

    # -- synchronous special case ----------------------------------------
    def run_batch(self, prompts: Sequence[str],
                  adaptive: Optional[bool] = None) -> List[Completed]:
        """Drain one prompt list synchronously — the old engine semantics
        as a special case of the segment machinery: greedy-clique grouping
        over the whole batch, per-group beta buckets, no arrivals, no
        trunk cache.  ``SageServingEngine.step()`` delegates here.

        Execution routes through ``serving.packing`` with phase-aligned
        segments: every drain tick issues ONE stacked launch per phase
        across ALL beta buckets (beta is per-row data — ``step_idx`` /
        ``fork_idx`` — not a pack-compatibility axis), instead of the old
        one-shared-plus-one-branch launch *per bucket*.  NFE accounting is
        unchanged: pad rows ride the pad-waste ledger, never NFE."""
        if not prompts:
            return []
        now = self._now(None)
        self._tick_now = now
        adaptive = (self.sage.adaptive_branch if adaptive is None
                    else adaptive)
        conds, pooled = self._embed(prompts)
        sim = grouping.similarity_matrix(pooled)
        cliques = grouping.greedy_clique_groups(
            sim, self.sage.tau_min, group_max=self.group_max)
        self.stats["requests"] += len(prompts)

        # one _Group per packed row (a clique larger than N occupies
        # multiple rows in flatten_groups order); every row inherits its
        # clique's beta bucket — per-clique, not batch-mean (a singleton's
        # pinned 1.0 min-sim must not drag other cliques' buckets)
        batch: List[_Group] = []
        # sync drain: no cache, and no fault injection — the drain loop
        # has no tick cadence to retry on, and run_batch is the
        # conformance oracle the chaos tests compare *against*
        cache, self.trunk_cache = self.trunk_cache, None
        faults, self.faults = self.faults, None
        try:
            for clique in cliques:
                beta = self._beta_bucket(
                    self._min_sim(sim[np.ix_(clique, clique)]), adaptive)
                for row in grouping.flatten_groups([clique],
                                                   self.group_size):
                    members = []
                    for m in row:
                        members.append(Request(
                            self._next_rid, prompts[m], now, None,
                            conds[m], pooled[m],
                            shape=tuple(self._latent_shape),
                            tier="standard", sampler=self.sage.sampler))
                        self._next_rid += 1
                    g = _Group(self._next_gid, members,
                               created_tick=self.ticks,
                               shape=tuple(self._latent_shape),
                               tier="standard", sampler=self.sage.sampler,
                               total_steps=self.tiers["standard"])
                    self._next_gid += 1
                    self.open_groups.append(g)
                    self._launch(g, now, adaptive, beta=beta)
                    batch.append(g)

            done: List[Completed] = []
            live = list(batch)
            # NOTE: the drain deliberately does NOT advance self.ticks —
            # wait counters of any STREAMING open groups on this
            # scheduler are measured in ticks, and a sync drain must not
            # age them toward a padded force-launch
            while live:
                self._advance_packed(live,
                                     slice_steps=self.sage.total_steps,
                                     align_phases=True)
                for g in list(live):
                    if g.state == "done":
                        done.extend(self._complete(g, now,
                                                   record_latency=False))
                        live.remove(g)
                        self.inflight.remove(g)
        finally:
            self.trunk_cache = cache
            self.faults = faults
        return done

    # -- reporting -------------------------------------------------------
    @property
    def cost_saving(self) -> float:
        return 1.0 - safe_ratio(self.stats["nfe"],
                                self.stats["nfe_independent"],
                                default=1.0)

    def summary(self) -> Dict[str, float]:
        """End-of-run rollup.  This is a *view over the registry-homed
        counters* (``self.stats`` and friends live in
        ``self.metrics``); zero-denominator ratios uniformly report
        ``0.0`` via :func:`telemetry.safe_ratio`."""
        lat = np.asarray(self.latencies, np.float64)
        out = {
            "requests": self.stats["requests"],
            "completed": self.stats["completed"],
            "nfe": self.stats["nfe"],
            "nfe_independent": self.stats["nfe_independent"],
            "nfe_saved_cache": self.stats["nfe_saved_cache"],
            "nfe_per_request": safe_ratio(self.stats["nfe"],
                                          self.stats["completed"]),
            "cost_saving": self.cost_saving,
            "latency_p50": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "latency_p95": float(np.percentile(lat, 95)) if lat.size else 0.0,
            "occupancy_mean": (float(np.mean(self.occupancy))
                               if self.occupancy else 0.0),
            "queue_depth_mean": (float(np.mean(self.queue_depth))
                                 if self.queue_depth else 0.0),
            "ticks": self.ticks,
            # packed-execution economics: launches_per_tick is the
            # dispatch pressure packing exists to collapse; pad_waste is
            # what it pays (fraction of launched latent rows that were
            # mask-0 padding)
            "launches": self.stats["launches"],
            "launches_per_tick": safe_ratio(self.stats["launches"],
                                            self.ticks),
            "pad_waste": safe_ratio(self.stats["pack_pad_rows"],
                                    self.stats["pack_rows"]),
        }
        # overload / robustness ledger + goodput (deadline-met
        # completions — the number a QoS policy is supposed to maximise
        # under saturation, where raw completion counts reward lateness)
        for k in ("shed", "shed_faulted", "degraded", "rejected_expired",
                  "preemptions", "resumes", "retries", "launch_faults",
                  "stalled_ticks", "deadline_met", "deadline_missed",
                  "nfe_wasted"):
            out[k] = self.stats[k]
        out["goodput"] = self.stats["deadline_met"]
        out["goodput_per_tick"] = safe_ratio(self.stats["deadline_met"],
                                             self.ticks)
        out["arrival_rate"] = self._arrival_rate
        out["backlog_ticks"] = self._backlog_ticks()
        for q, cs in sorted(self.class_stats.items()):
            for k, v in sorted(cs.items()):
                out[f"{q}_{k}"] = v
        for q, lats in sorted(self.class_latencies.items()):
            a = np.asarray(lats, np.float64)
            out[f"{q}_latency_p50"] = (float(np.percentile(a, 50))
                                       if a.size else 0.0)
            out[f"{q}_latency_p95"] = (float(np.percentile(a, 95))
                                       if a.size else 0.0)
        # hetero rollups (additive keys — homogeneous runs emit exactly
        # one tier and one shape bucket)
        for t, ts in sorted(self.tier_stats.items()):
            for k, v in sorted(ts.items()):
                out[f"tier_{t}_{k}"] = v
        for s, ss in sorted(self.shape_stats.items()):
            for k, v in sorted(ss.items()):
                out[f"shape_{s}_{k}"] = v
        if self.trunk_cache is not None:
            # hit accounting is policy-visible: exact-key hits and
            # admission rejections surface next to the hit rate so a
            # mis-tuned PopularityAdmission threshold shows up here
            # instead of as a silent hit-rate collapse
            out["cache_hits"] = self.trunk_cache.stats["hits"]
            out["cache_exact_hits"] = self.trunk_cache.stats["exact_hits"]
            out["cache_hits_hbm"] = self.trunk_cache.stats["hits_hbm"]
            out["cache_hits_host"] = self.trunk_cache.stats["hits_host"]
            out["cache_admission_rejects"] = \
                self.trunk_cache.stats["admission_rejects"]
            out["cache_hit_rate"] = self.trunk_cache.hit_rate
            out["cache_entries"] = len(self.trunk_cache)
            out["cache_bytes"] = self.trunk_cache.bytes
            # tier + index health: spills/promotions trace working-set
            # churn between the HBM budget and the host spill tier, and
            # the index name records which candidate generator served the
            # similarity path (scan oracle vs LSH)
            out["cache_index"] = self.trunk_cache.index.name
            out["cache_spills"] = self.trunk_cache.stats["spills"]
            out["cache_promotions"] = self.trunk_cache.stats["promotions"]
            out["cache_hbm_bytes"] = self.trunk_cache.tier_bytes["hbm"]
            out["cache_host_bytes"] = self.trunk_cache.tier_bytes["host"]
        return out
