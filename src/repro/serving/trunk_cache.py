"""Cross-batch semantic trunk cache (the serving analogue of
``shared_prefill``'s prefix cache, applied to diffusion trajectories).

SAGE shares the early, semantically-coarse sampling phase *within* a
group; this cache extends the sharing *across time*: when a group finishes
its shared phase, the trunk state — the :class:`SampleCarry` at the branch
point — is stored under the group's mean prompt embedding.  A later group
whose centroid is close enough (cosine >= ``tau_trunk``) skips its shared
phase entirely and forks straight into branching ("Reusing Computation in
Text-to-Image Diffusion", arXiv 2508.21032, finds this cross-query reuse
of early denoising the dominant lever for image-set workloads).

Correctness note: branch trajectories forked from a cached trunk are
*exact* for the cached centroid's conditioning and approximate for the new
group's (the trunk was denoised under the cached group's c̄) — the same
kind of approximation as the paper's within-group sharing, governed by the
same similarity-threshold logic, so ``tau_trunk`` should sit well above
``tau_min``.  Hits additionally require an exact match of everything else
that shapes the trunk: sampler config, schedule bucket (beta), latent
shape and *payload type* are all part of the compatibility key.  Under
heterogeneous serving these are *per-group* attributes, not engine
globals — the scheduler's cfg_key bakes each group's own sampler and
tier step budget, and the shape key is the group's own latent shape, so
a draft-tier dpmpp thumbnail can never satisfy a premium ddim hi-res
lookup however close their centroids sit.  (The RNG
fold that drew the trunk's init noise is stored as provenance metadata
only — reusing a trunk deliberately replaces the hitting group's own
noise stream.)

Keying is two-level, like a prefix cache with fuzzy tags:

* a *quantized* centroid (rounded to ``quant_decimals``) gives an O(1)
  exact-hit dict key for repeated themes; if the resident entry under a
  colliding quantized key fails the cosine re-check, the lookup falls
  through to the similarity search — a collision must never mask a
  compatible near-duplicate stored under a different key;
* a similarity search over the entry set catches near-duplicates under
  ``tau_trunk``.  Candidate generation is pluggable
  (``serving.ann_index``): ``index="scan"`` is the exact O(N) oracle,
  ``index="lsh"`` narrows to sign-random-projection LSH buckets.  Either
  way every candidate is re-verified against the true cosine threshold,
  so an approximate index can lower recall but can never produce a false
  accept.

Payload types: the same cache serves diffusion trunks
(``payload="trunk"``, the scheduler's default) and AR prefix trunks
(``payload="ar_prefix"``, see ``serving.shared_prefill``) — one
semantic-reuse layer, namespaced by the payload field in the key so the
two kinds can never satisfy each other's lookups.

Storage is *tiered*: entries live in an HBM working set bounded by
``max_bytes``; when that budget overflows, victims spill to a host-RAM
tier (bounded by ``host_bytes``, arrays committed to host numpy) instead
of being dropped, and a hit on a spilled entry promotes it back to HBM.
``spills`` / ``promotions`` / ``tier_bytes`` ride the stats ledger.  With
``host_bytes=0`` (the default) the spill tier is disabled and overflow
evicts outright — the pre-tier behavior.

Storage and eviction are policy-driven (``serving.policies``): a
:class:`~repro.serving.policies.CacheAdmission` object decides whether a
completed trunk earns bytes at all (``PopularityAdmission`` only stores
keys whose demand count crossed a threshold; rejections are counted in
``stats['admission_rejects']``) and which entry each tier's byte budget
demotes or evicts first (the ``tier`` kwarg names the tier under
pressure).  Every ``lookup`` — exact-key hit, similarity hit, or miss —
ticks the requester's quantized key through ``admission.on_lookup`` so
the popularity signal measures demand, not residency (the exact-key path
bypassing the counter was a bug).  Bytes are accounted with
``kvcache.cache_bytes`` over the stored arrays.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Hashable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.ann_index import CentroidIndex, make_index
from repro.serving.faults import FaultPlan, array_crc, corrupt_array
from repro.serving.kvcache import cache_bytes
from repro.serving.policies import CacheAdmission, make_cache_admission
from repro.serving.telemetry import safe_ratio

HBM, HOST = "hbm", "host"


@dataclass
class TrunkEntry:
    """One completed shared phase: the carry at the branch point."""
    z: Any                       # (K=1, H, W, C) trunk latent at T* — or,
    #                              for payload="ar_prefix", the (logits,
    #                              kv-cache) pytree at the prefix boundary
    eps_prev: Any                # solver history at T*, or None (the branch
    #                              fork restarts history — see fork_carry —
    #                              so TrunkCache(store_history=False) drops
    #                              it to double capacity per byte)
    step_idx: int                # grid position of z (== n_shared); for
    #                              ar_prefix payloads, the prefix length
    beta_bucket: float           # share-ratio bucket the trunk ran under
    rng_fold: int                # fold of the engine key that drew the noise
    centroid: np.ndarray         # unit-norm mean prompt embedding
    cfg_key: Hashable            # sampler/schedule compatibility fingerprint
    payload: str = "trunk"       # semantic-reuse namespace: "trunk"
    #                              (diffusion branch-point carry) or
    #                              "ar_prefix" (LLM prefix trunk)
    tier: str = HBM              # residency tier, maintained by the cache
    nbytes: int = 0
    crc: Optional[int] = None    # integrity fingerprint of z's bytes —
    #                              validated on every hit, so a corrupted
    #                              payload reads as a miss, never as a
    #                              silently-wrong trunk

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = cache_bytes((self.z, self.eps_prev))
        if self.crc is None:
            self.crc = array_crc(self.z)


def _unit(v: np.ndarray) -> np.ndarray:
    v = np.asarray(v, np.float32).reshape(-1)
    return v / max(float(np.linalg.norm(v)), 1e-8)


def _to_host(x):
    """Commit a payload pytree to host RAM (numpy leaves, bytes
    unchanged — the CRC fingerprint survives the tier move)."""
    return jax.tree.map(np.asarray, x)


def _to_device(x):
    """Bring a spilled payload back onto the device default."""
    return jax.tree.map(jnp.asarray, x)


class TrunkCache:
    """Tiered LRU map: quantized group centroid -> :class:`TrunkEntry`.

    ``lookup`` is exact-key first (quantized centroid), then a
    similarity search over index candidates; both paths require
    ``cfg_key``/``beta_bucket``/latent-shape/payload equality and the
    exact ``tau_trunk`` cosine.
    """

    def __init__(self, tau_trunk: float = 0.95,
                 max_bytes: int = 64 * 1024 * 1024,
                 quant_decimals: int = 2, store_history: bool = True,
                 admission: Union[str, CacheAdmission, None] = None,
                 faults: Optional[FaultPlan] = None,
                 index: Union[str, CentroidIndex, None] = "scan",
                 host_bytes: int = 0):
        """``max_bytes`` bounds the HBM working set; ``host_bytes`` sizes
        the host-RAM spill tier (0 disables spilling — HBM overflow
        evicts outright, the pre-tier behavior).

        ``index`` selects candidate generation for the similarity search:
        ``"scan"`` (exact O(N) oracle) or ``"lsh"``
        (sign-random-projection buckets, see ``serving.ann_index``), or a
        :class:`~repro.serving.ann_index.CentroidIndex` instance.
        Candidates are always re-verified against the true cosine, so the
        index choice can never cause a false accept.

        ``store_history=False`` drops the ``eps_prev`` array from stored
        entries (halving bytes per trunk, doubling capacity under the
        budget): the restore path *forks* — solver history restarts at the
        branch point — so the history is only needed if trunks are later
        resumed mid-shared-phase rather than forked.

        ``admission`` is a :class:`~repro.serving.policies.CacheAdmission`
        instance or name (``"always"`` — the default store-everything LRU,
        or ``"popularity"`` — threshold admission + cold-first eviction).

        ``faults`` is an optional
        :class:`~repro.serving.faults.FaultPlan` injecting forced misses
        and payload corruption on the hit path (chaos testing); the CRC
        integrity gate that catches corruption is always on.
        """
        if not 0.0 < tau_trunk <= 1.0:
            raise ValueError(f"tau_trunk must be in (0, 1], got {tau_trunk}")
        if host_bytes < 0:
            raise ValueError(f"host_bytes must be >= 0, got {host_bytes}")
        self.tau_trunk = tau_trunk
        self.max_bytes = max_bytes
        self.host_bytes = host_bytes
        self.quant_decimals = quant_decimals
        self.store_history = store_history
        self.admission = make_cache_admission(admission)
        self.faults = faults
        self.index = make_index(index)
        self._entries: "OrderedDict[Tuple, TrunkEntry]" = OrderedDict()
        self.bytes = 0
        self.tier_bytes = {HBM: 0, HOST: 0}
        self.stats = {"hits": 0, "exact_hits": 0, "misses": 0,
                      "hits_hbm": 0, "hits_host": 0,
                      "inserts": 0, "evictions": 0, "overwrites": 0,
                      "admission_rejects": 0, "fault_forced_misses": 0,
                      "integrity_drops": 0, "spills": 0, "promotions": 0}

    # ------------------------------------------------------------------
    def _quant_key(self, centroid: np.ndarray, beta_bucket: float,
                   cfg_key: Hashable, shape: Tuple[int, ...],
                   payload: str = "trunk") -> Tuple:
        q = np.round(_unit(centroid), self.quant_decimals)
        # -0.0 and 0.0 quantize to different bytes; canonicalise
        q = q + 0.0
        return (q.tobytes(), round(beta_bucket, 4), cfg_key, shape, payload)

    # -- tier mechanics ------------------------------------------------
    def _remove(self, key: Tuple) -> TrunkEntry:
        """Drop ``key`` from the store, ledger and index (no stats)."""
        entry = self._entries.pop(key)
        self.bytes -= entry.nbytes
        self.tier_bytes[entry.tier] -= entry.nbytes
        self.index.discard(key)
        return entry

    def _spill(self, key: Tuple) -> None:
        """Demote an HBM entry to the host tier (payload committed to
        host numpy; bytes move between tier ledgers, total unchanged)."""
        entry = self._entries[key]
        entry.z = _to_host(entry.z)
        entry.eps_prev = _to_host(entry.eps_prev)
        entry.tier = HOST
        self.tier_bytes[HBM] -= entry.nbytes
        self.tier_bytes[HOST] += entry.nbytes
        self.stats["spills"] += 1

    def _promote(self, key: Tuple) -> None:
        """Promote-on-hit: bring a spilled entry back to HBM."""
        entry = self._entries[key]
        entry.z = _to_device(entry.z)
        entry.eps_prev = _to_device(entry.eps_prev)
        entry.tier = HBM
        self.tier_bytes[HOST] -= entry.nbytes
        self.tier_bytes[HBM] += entry.nbytes
        self.stats["promotions"] += 1

    def _tier_keys(self, tier: str) -> List[Tuple]:
        """Keys resident in ``tier``, LRU -> MRU order."""
        return [k for k, e in self._entries.items() if e.tier == tier]

    def _enforce_budgets(self) -> None:
        """Settle both tier budgets: HBM overflow spills to host (or
        evicts when the spill tier is disabled), host overflow evicts.
        The newest/last HBM entry is never forced out by its own size —
        an oversized single trunk stays resident (pre-tier semantics)."""
        while self.tier_bytes[HBM] > self.max_bytes:
            hbm = self._tier_keys(HBM)
            if len(hbm) <= 1:
                break
            victim = self.admission.victim(hbm, tier=HBM)
            if self.host_bytes > 0:
                self._spill(victim)
            else:
                self._remove(victim)
                self.stats["evictions"] += 1
        while self.tier_bytes[HOST] > self.host_bytes:
            host = self._tier_keys(HOST)
            if not host:
                break
            victim = self.admission.victim(host, tier=HOST)
            self._remove(victim)
            self.stats["evictions"] += 1

    # ------------------------------------------------------------------
    def lookup(self, centroid: np.ndarray, beta_bucket: float,
               cfg_key: Hashable, shape: Tuple[int, ...],
               payload: str = "trunk") -> Optional[TrunkEntry]:
        """Best compatible entry with cosine >= tau_trunk, else None."""
        c = _unit(centroid)
        key = self._quant_key(centroid, beta_bucket, cfg_key, shape,
                              payload)
        # demand signal first, on EVERY lookup path — the exact-key hit
        # below must not bypass the popularity counter (hit accounting is
        # policy-visible: see stats['admission_rejects'] / summary())
        self.admission.on_lookup(key)
        hit = self._entries.get(key)
        # quantization is coarser than tau_trunk can be (each component
        # rounds by up to 0.5 * 10^-quant_decimals), so an exact-key hit
        # must still clear the cosine threshold
        if hit is not None and float(hit.centroid @ c) >= self.tau_trunk:
            hit_key, exact = key, True
        else:
            # no exact entry, or a quantized-key collision that failed the
            # re-check: fall through to the similarity search — the
            # colliding resident must not mask a compatible near-duplicate
            # stored under a different quantized key
            hit_key, best_sim, exact = None, self.tau_trunk, False
            cand = self.index.candidates(c)
            items = (self._entries.items() if cand is None
                     else ((k, self._entries[k]) for k in cand
                           if k in self._entries))
            compat = (round(beta_bucket, 4), cfg_key, shape, payload)
            for k, e in items:
                if (k[1], k[2], k[3], k[4]) != compat:
                    continue
                sim = float(e.centroid @ c)
                if sim >= best_sim:
                    hit_key, best_sim = k, sim
        if hit_key is None:
            self.stats["misses"] += 1
            return None
        entry = self._entries[hit_key]
        # fault injection rides the hit path only (a miss has nothing to
        # lose): a forced miss leaves the entry intact, corruption
        # damages the stored payload and must be caught below
        if self.faults is not None:
            if self.faults.cache_miss():
                self.stats["fault_forced_misses"] += 1
                self.stats["misses"] += 1
                return None
            if self.faults.cache_corrupt():
                entry.z = corrupt_array(entry.z)
        # integrity gate (always on, not only under injection): a stored
        # trunk whose bytes no longer match the insert-time CRC is
        # dropped and reported as a miss — recomputing the shared phase
        # is exact, silently denoising from a damaged trunk is not
        if entry.crc != array_crc(entry.z):
            self._remove(hit_key)
            self.stats["integrity_drops"] += 1
            self.stats["misses"] += 1
            return None
        # per-tier hit attribution records the tier the entry was FOUND
        # in (pre-promotion) — the number capacity planning cares about
        self.stats["hits_" + entry.tier] += 1
        self._entries.move_to_end(hit_key)
        if entry.tier == HOST:
            # promote-on-hit: the caller is about to fork from this trunk,
            # so it belongs in the working set; promotion may spill a
            # colder HBM entry in its place
            self._promote(hit_key)
            self._enforce_budgets()
        self.stats["hits"] += 1
        if exact:
            self.stats["exact_hits"] += 1
        return entry

    def insert(self, entry: TrunkEntry,
               shape: Optional[Tuple[int, ...]] = None) -> bool:
        """Store a completed trunk if the admission policy admits its key;
        returns whether the entry was stored."""
        entry.centroid = _unit(entry.centroid)
        shape = shape if shape is not None else tuple(np.shape(entry.z))
        key = self._quant_key(entry.centroid, entry.beta_bucket,
                              entry.cfg_key, shape, entry.payload)
        if not self.admission.admit(key):
            self.stats["admission_rejects"] += 1
            return False
        if not self.store_history and entry.eps_prev is not None:
            entry.eps_prev = None
            entry.nbytes = cache_bytes((entry.z,))
        # overwrite of an existing exact key is evict-then-insert: the old
        # entry's bytes leave the ledger before the new entry's arrive, so
        # cache_bytes can never double-count a key (regression:
        # tests/test_serving_scheduler.py::test_trunk_cache_overwrite_*)
        if key in self._entries:
            self._remove(key)
            self.stats["overwrites"] += 1
        entry.tier = HBM                 # fresh trunks enter the working set
        self._entries[key] = entry
        self.bytes += entry.nbytes
        self.tier_bytes[HBM] += entry.nbytes
        self.index.add(key, entry.centroid)
        self.stats["inserts"] += 1
        self._enforce_budgets()
        return True

    # ------------------------------------------------------------------
    def ledger_bytes(self) -> int:
        """Recount ``bytes`` from the stored entries (invariant probe:
        must always equal the incrementally-maintained ``self.bytes``)."""
        return sum(e.nbytes for e in self._entries.values())

    def tier_ledger(self) -> dict:
        """Per-tier recount (invariant probe for ``tier_bytes``: the two
        must match, and their sum must equal ``bytes``)."""
        out = {HBM: 0, HOST: 0}
        for e in self._entries.values():
            out[e.tier] += e.nbytes
        return out

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        return safe_ratio(self.stats["hits"],
                          self.stats["hits"] + self.stats["misses"])
