"""Cross-batch semantic trunk cache (the serving analogue of
``shared_prefill``'s prefix cache, applied to diffusion trajectories).

SAGE shares the early, semantically-coarse sampling phase *within* a
group; this cache extends the sharing *across time*: when a group finishes
its shared phase, the trunk state — the :class:`SampleCarry` at the branch
point — is stored under the group's mean prompt embedding.  A later group
whose centroid is close enough (cosine >= ``tau_trunk``) skips its shared
phase entirely and forks straight into branching ("Reusing Computation in
Text-to-Image Diffusion", arXiv 2508.21032, finds this cross-query reuse
of early denoising the dominant lever for image-set workloads).

Correctness note: branch trajectories forked from a cached trunk are
*exact* for the cached centroid's conditioning and approximate for the new
group's (the trunk was denoised under the cached group's c̄) — the same
kind of approximation as the paper's within-group sharing, governed by the
same similarity-threshold logic, so ``tau_trunk`` should sit well above
``tau_min``.  Hits additionally require an exact match of everything else
that shapes the trunk: sampler config, schedule bucket (beta) and latent
shape are all part of the compatibility key.  (The RNG fold that drew the
trunk's init noise is stored as provenance metadata only — reusing a
trunk deliberately replaces the hitting group's own noise stream.)

Keying is two-level, like a prefix cache with fuzzy tags:

* a *quantized* centroid (rounded to ``quant_decimals``) gives an O(1)
  exact-hit dict key for repeated themes;
* a linear cosine scan over the (small, byte-budgeted) entry set catches
  near-duplicates under ``tau_trunk``.

Storage and eviction are policy-driven (``serving.policies``): a
:class:`~repro.serving.policies.CacheAdmission` object decides whether a
completed trunk earns bytes at all (``PopularityAdmission`` only stores
keys whose demand count crossed a threshold; rejections are counted in
``stats['admission_rejects']``) and which entry the byte budget evicts
first (cold-first under popularity, plain LRU under the default
:class:`~repro.serving.policies.AdmitAll`).  Every ``lookup`` — exact-key
hit, scan hit, or miss — ticks the requester's quantized key through
``admission.on_lookup`` so the popularity signal measures demand, not
residency (the exact-key path bypassing the counter was a bug).  Bytes
are accounted with ``kvcache.cache_bytes`` over the stored arrays.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Hashable, Optional, Tuple, Union

import numpy as np

from repro.serving.faults import FaultPlan, array_crc, corrupt_array
from repro.serving.kvcache import cache_bytes
from repro.serving.policies import CacheAdmission, make_cache_admission


@dataclass
class TrunkEntry:
    """One completed shared phase: the carry at the branch point."""
    z: Any                       # (K=1, H, W, C) trunk latent at T*
    eps_prev: Any                # solver history at T*, or None (the branch
    #                              fork restarts history — see fork_carry —
    #                              so TrunkCache(store_history=False) drops
    #                              it to double capacity per byte)
    step_idx: int                # grid position of z (== n_shared)
    beta_bucket: float           # share-ratio bucket the trunk ran under
    rng_fold: int                # fold of the engine key that drew the noise
    centroid: np.ndarray         # unit-norm mean prompt embedding
    cfg_key: Hashable            # sampler/schedule compatibility fingerprint
    nbytes: int = 0
    crc: Optional[int] = None    # integrity fingerprint of z's bytes —
    #                              validated on every hit, so a corrupted
    #                              payload reads as a miss, never as a
    #                              silently-wrong trunk

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = cache_bytes((self.z, self.eps_prev))
        if self.crc is None:
            self.crc = array_crc(self.z)


def _unit(v: np.ndarray) -> np.ndarray:
    v = np.asarray(v, np.float32).reshape(-1)
    return v / max(float(np.linalg.norm(v)), 1e-8)


class TrunkCache:
    """LRU map: quantized group centroid -> :class:`TrunkEntry`.

    ``lookup`` is exact-key first (quantized centroid), cosine scan second;
    both paths require ``cfg_key``/``beta_bucket``/latent-shape equality.
    """

    def __init__(self, tau_trunk: float = 0.95,
                 max_bytes: int = 64 * 1024 * 1024,
                 quant_decimals: int = 2, store_history: bool = True,
                 admission: Union[str, CacheAdmission, None] = None,
                 faults: Optional[FaultPlan] = None):
        """``store_history=False`` drops the ``eps_prev`` array from stored
        entries (halving bytes per trunk, doubling capacity under the
        budget): the restore path *forks* — solver history restarts at the
        branch point — so the history is only needed if trunks are later
        resumed mid-shared-phase rather than forked.

        ``admission`` is a :class:`~repro.serving.policies.CacheAdmission`
        instance or name (``"always"`` — the default store-everything LRU,
        or ``"popularity"`` — threshold admission + cold-first eviction).

        ``faults`` is an optional
        :class:`~repro.serving.faults.FaultPlan` injecting forced misses
        and payload corruption on the hit path (chaos testing); the CRC
        integrity gate that catches corruption is always on.
        """
        if not 0.0 < tau_trunk <= 1.0:
            raise ValueError(f"tau_trunk must be in (0, 1], got {tau_trunk}")
        self.tau_trunk = tau_trunk
        self.max_bytes = max_bytes
        self.quant_decimals = quant_decimals
        self.store_history = store_history
        self.admission = make_cache_admission(admission)
        self.faults = faults
        self._entries: "OrderedDict[Tuple, TrunkEntry]" = OrderedDict()
        self.bytes = 0
        self.stats = {"hits": 0, "exact_hits": 0, "misses": 0,
                      "inserts": 0, "evictions": 0, "overwrites": 0,
                      "admission_rejects": 0, "fault_forced_misses": 0,
                      "integrity_drops": 0}

    # ------------------------------------------------------------------
    def _quant_key(self, centroid: np.ndarray, beta_bucket: float,
                   cfg_key: Hashable, shape: Tuple[int, ...]) -> Tuple:
        q = np.round(_unit(centroid), self.quant_decimals)
        # -0.0 and 0.0 quantize to different bytes; canonicalise
        q = q + 0.0
        return (q.tobytes(), round(beta_bucket, 4), cfg_key, shape)

    def lookup(self, centroid: np.ndarray, beta_bucket: float,
               cfg_key: Hashable, shape: Tuple[int, ...]
               ) -> Optional[TrunkEntry]:
        """Best compatible entry with cosine >= tau_trunk, else None."""
        c = _unit(centroid)
        key = self._quant_key(centroid, beta_bucket, cfg_key, shape)
        # demand signal first, on EVERY lookup path — the exact-key hit
        # below must not bypass the popularity counter (hit accounting is
        # policy-visible: see stats['admission_rejects'] / summary())
        self.admission.on_lookup(key)
        hit = self._entries.get(key)
        # quantization is coarser than tau_trunk can be (each component
        # rounds by up to 0.5 * 10^-quant_decimals), so an exact-key hit
        # must still clear the cosine threshold
        if hit is not None and float(hit.centroid @ c) >= self.tau_trunk:
            hit_key, exact = key, True
        else:
            hit_key, best_sim = None, self.tau_trunk
            for k, e in self._entries.items():
                if (k[1], k[2], k[3]) != (round(beta_bucket, 4), cfg_key,
                                          shape):
                    continue
                sim = float(e.centroid @ c)
                if sim >= best_sim:
                    hit_key, best_sim = k, sim
            exact = False
        if hit_key is None:
            self.stats["misses"] += 1
            return None
        entry = self._entries[hit_key]
        # fault injection rides the hit path only (a miss has nothing to
        # lose): a forced miss leaves the entry intact, corruption
        # damages the stored payload and must be caught below
        if self.faults is not None:
            if self.faults.cache_miss():
                self.stats["fault_forced_misses"] += 1
                self.stats["misses"] += 1
                return None
            if self.faults.cache_corrupt():
                entry.z = corrupt_array(entry.z)
        # integrity gate (always on, not only under injection): a stored
        # trunk whose bytes no longer match the insert-time CRC is
        # dropped and reported as a miss — recomputing the shared phase
        # is exact, silently denoising from a damaged trunk is not
        if entry.crc != array_crc(entry.z):
            self._entries.pop(hit_key)
            self.bytes -= entry.nbytes
            self.stats["integrity_drops"] += 1
            self.stats["misses"] += 1
            return None
        self._entries.move_to_end(hit_key)
        self.stats["hits"] += 1
        if exact:
            self.stats["exact_hits"] += 1
        return entry

    def insert(self, entry: TrunkEntry,
               shape: Optional[Tuple[int, ...]] = None) -> bool:
        """Store a completed trunk if the admission policy admits its key;
        returns whether the entry was stored."""
        entry.centroid = _unit(entry.centroid)
        shape = shape if shape is not None else tuple(np.shape(entry.z))
        key = self._quant_key(entry.centroid, entry.beta_bucket,
                              entry.cfg_key, shape)
        if not self.admission.admit(key):
            self.stats["admission_rejects"] += 1
            return False
        if not self.store_history and entry.eps_prev is not None:
            entry.eps_prev = None
            entry.nbytes = cache_bytes((entry.z,))
        # overwrite of an existing exact key is evict-then-insert: the old
        # entry's bytes leave the ledger before the new entry's arrive, so
        # cache_bytes can never double-count a key (regression:
        # tests/test_serving_scheduler.py::test_trunk_cache_overwrite_*)
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes -= old.nbytes
            self.stats["overwrites"] += 1
        self._entries[key] = entry
        self.bytes += entry.nbytes
        self.stats["inserts"] += 1
        while self.bytes > self.max_bytes and len(self._entries) > 1:
            victim = self.admission.victim(self._entries.keys())
            evicted = self._entries.pop(victim)    # cold-first, or LRU end
            self.bytes -= evicted.nbytes
            self.stats["evictions"] += 1
        return True

    # ------------------------------------------------------------------
    def ledger_bytes(self) -> int:
        """Recount ``bytes`` from the stored entries (invariant probe:
        must always equal the incrementally-maintained ``self.bytes``)."""
        return sum(e.nbytes for e in self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        n = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / n if n else 0.0
