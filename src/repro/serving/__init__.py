from repro.serving.ann_index import (CentroidIndex, LshIndex, ScanIndex,
                                     make_index)
from repro.serving.engine import Completed, SageServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.packing import PackKey, build_packs
from repro.serving.policies import (AdaptivePadAwarePolicy, AdmissionContext,
                                    AdmissionPolicy, AdmitAll,
                                    AdmitAllRequests, CacheAdmission,
                                    EagerPolicy, LaunchContext, LaunchPolicy,
                                    PadAwarePolicy, PopularityAdmission,
                                    SaturationAdmission,
                                    make_admission_policy,
                                    make_cache_admission, make_launch_order,
                                    make_launch_policy)
from repro.serving.scheduler import RequestScheduler
from repro.serving.shared_prefill import (cached_prefix_prefill,
                                          group_requests,
                                          shared_prefix_prefill)
from repro.serving.telemetry import (Histogram, MetricsRegistry, Tracer,
                                     safe_ratio)
from repro.serving.trunk_cache import TrunkCache, TrunkEntry
