from repro.serving.engine import SageServingEngine
from repro.serving.shared_prefill import group_requests, shared_prefix_prefill
