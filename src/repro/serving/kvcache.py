"""KV/state-cache manipulation for the serving engine."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def fork_cache(cache: Any, n: int) -> Any:
    """Replicate a batch-1-per-group cache along the member axis:
    (B, ...) -> (B*n, ...).  This is SAGE's branch point for AR serving —
    O(bytes) for attention KV, O(d_state) for SSM/RG-LRU states (the SSM
    fork is the cheapest, see DESIGN.md §4)."""
    def rep(x):
        if x.ndim == 0:
            return x
        return jnp.repeat(x, n, axis=0)
    return jax.tree.map(rep, cache)


def fork_model_cache(cache: Any, n: int) -> Any:
    """Fork a transformer-runtime cache ({'prefix','blocks','suffix'}):
    scanned 'blocks' leaves carry a leading (n_blocks) stack dim, so their
    batch axis is 1; prefix/suffix leaves fork on axis 0."""
    def rep(ax):
        return lambda x: x if x.ndim == 0 else jnp.repeat(x, n, axis=ax)

    return {"prefix": jax.tree.map(rep(0), cache["prefix"]),
            "blocks": jax.tree.map(rep(1), cache["blocks"]),
            "suffix": jax.tree.map(rep(0), cache["suffix"])}


def select_rows(cache: Any, idx) -> Any:
    """Gather member rows of a batched cache (request eviction/reorder)."""
    return jax.tree.map(lambda x: x if x.ndim == 0 else jnp.take(x, idx, 0),
                        cache)


def cache_bytes(cache: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
