"""Pluggable centroid indexes for the trunk cache's similarity search.

``TrunkCache.lookup`` was an exact-key dict plus an O(N) cosine scan —
fine for the dozens of entries in the early benchmarks, a wall at the
production entry counts the ROADMAP north star targets.  This module
makes the *candidate generation* step pluggable while keeping the
acceptance test exact:

* :class:`ScanIndex` (``index="scan"``) is the oracle: it declines to
  narrow the candidate set (``candidates`` returns ``None``), so the
  cache scans every resident entry exactly as before.  Every other index
  is judged against it — the differential suite in
  ``tests/test_ann_index.py`` measures recall relative to this scan.
* :class:`LshIndex` (``index="lsh"``) buckets centroids by
  sign-random-projection LSH (Charikar's SimHash): ``n_tables``
  independent hash tables, each hashing a centroid to an ``n_bits``-bit
  code via the signs of random hyperplane projections.  A lookup probes
  its bucket in every table and returns the union as candidates.  The
  projection is computed with ``jnp`` so the hash stays jax-native (on an
  accelerator the planes matmul rides the device; under interpret-mode
  CPU it is a single dispatched dot).

Safety contract — and the reason this layering cannot create wrong hits:
an index only *proposes* candidates.  The cache re-verifies every
candidate against the true ``tau_trunk`` cosine before accepting it, so
a false accept is impossible by construction; the only failure mode an
approximate index can introduce is a *miss* (recall < 1), and a trunk
miss is always safe — the group just computes its own shared phase
exactly.  Two unit vectors with cosine ``s`` land on the same side of a
random hyperplane with probability ``1 - arccos(s)/pi`` (≈ 0.86 at
s = 0.90), so per-table collision is ``p^n_bits`` and overall recall is
``1 - (1 - p^n_bits)^n_tables`` — the defaults (8 tables × 6 bits) put
recall above 0.95 for every ``tau_trunk`` ≥ 0.90 (asserted empirically
by the differential suite).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import (Dict, List, Optional, Protocol, Tuple, Union,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class CentroidIndex(Protocol):
    """Candidate generator over (key, unit-centroid) pairs.

    ``candidates`` may return ``None`` meaning "no narrowing — scan
    everything" (the exact oracle), or a list of keys to re-verify.  The
    cache owns the truth: candidates are always re-checked against the
    exact cosine threshold, so an index trades recall, never precision.
    """

    name: str

    def add(self, key: Tuple, centroid: np.ndarray) -> None: ...

    def discard(self, key: Tuple) -> None: ...

    def candidates(self, centroid: np.ndarray) -> Optional[List[Tuple]]: ...

    def rebuild(self) -> None: ...

    def __len__(self) -> int: ...


class ScanIndex:
    """The exact oracle: no candidate narrowing, the cache scans all
    entries in residency (LRU) order — bitwise the pre-index behavior."""

    name = "scan"

    def __init__(self):
        self._keys: "OrderedDict[Tuple, None]" = OrderedDict()

    def add(self, key: Tuple, centroid: np.ndarray) -> None:
        self._keys[key] = None

    def discard(self, key: Tuple) -> None:
        self._keys.pop(key, None)

    def candidates(self, centroid: np.ndarray) -> Optional[List[Tuple]]:
        return None                      # sentinel: scan every entry

    def rebuild(self) -> None:
        pass

    def __len__(self) -> int:
        return len(self._keys)


class LshIndex:
    """Sign-random-projection (SimHash) LSH over unit centroids.

    ``n_tables`` hash tables, each an ``n_bits``-bit signature from the
    signs of ``planes @ centroid``; hyperplanes are drawn per embedding
    dim from ``jax.random`` (seeded, so signatures are reproducible and
    a rebuilt index hashes identically).  Buckets are keyed
    ``(dim, table, code)`` — centroids of different dims can never
    collide.  ``candidates`` returns the union of the probe buckets in
    first-inserted order (deterministic across runs).
    """

    name = "lsh"

    def __init__(self, n_tables: int = 8, n_bits: int = 6, seed: int = 0):
        if n_tables < 1 or n_bits < 1:
            raise ValueError(f"n_tables/n_bits must be >= 1, "
                             f"got {n_tables}/{n_bits}")
        self.n_tables = n_tables
        self.n_bits = n_bits
        self.seed = seed
        self._planes: Dict[int, jnp.ndarray] = {}       # dim -> projection
        # (dim, table, code) -> ordered set of keys in that bucket
        self._buckets: Dict[Tuple[int, int, int],
                            "OrderedDict[Tuple, None]"] = {}
        # key -> (dim, per-table codes, centroid) for removal + rebuild
        self._sigs: Dict[Tuple, Tuple[int, Tuple[int, ...],
                                      np.ndarray]] = {}
        self.stats = {"adds": 0, "removes": 0, "lookups": 0,
                      "candidates": 0, "rehashes": 0}

    # -- hashing -------------------------------------------------------
    def _planes_for(self, dim: int) -> jnp.ndarray:
        planes = self._planes.get(dim)
        if planes is None:
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed), dim)
            planes = jax.random.normal(
                key, (self.n_tables * self.n_bits, dim), dtype=jnp.float32)
            self._planes[dim] = planes
        return planes

    def signature(self, centroid: np.ndarray
                  ) -> Tuple[int, Tuple[int, ...]]:
        """(dim, per-table bucket codes) for a unit centroid."""
        c = np.asarray(centroid, np.float32).reshape(-1)
        dim = c.shape[0]
        # jax-native projection: one planes@c dot per hash
        bits = np.asarray(self._planes_for(dim) @ jnp.asarray(c)) >= 0.0
        weights = 1 << np.arange(self.n_bits)
        codes = tuple(
            int(bits[t * self.n_bits:(t + 1) * self.n_bits] @ weights)
            for t in range(self.n_tables))
        return dim, codes

    # -- mutation ------------------------------------------------------
    def add(self, key: Tuple, centroid: np.ndarray) -> None:
        if key in self._sigs:            # re-add = overwrite signature
            self.discard(key)
        c = np.asarray(centroid, np.float32).reshape(-1)
        dim, codes = self.signature(c)
        for t, code in enumerate(codes):
            self._buckets.setdefault((dim, t, code),
                                     OrderedDict())[key] = None
        self._sigs[key] = (dim, codes, c)
        self.stats["adds"] += 1

    def discard(self, key: Tuple) -> None:
        sig = self._sigs.pop(key, None)
        if sig is None:
            return
        dim, codes, _ = sig
        for t, code in enumerate(codes):
            bucket = self._buckets.get((dim, t, code))
            if bucket is not None:
                bucket.pop(key, None)
                if not bucket:
                    del self._buckets[(dim, t, code)]
        self.stats["removes"] += 1

    # -- query ---------------------------------------------------------
    def candidates(self, centroid: np.ndarray) -> List[Tuple]:
        self.stats["lookups"] += 1
        if not self._sigs:               # empty index: nothing to probe
            return []
        dim, codes = self.signature(centroid)
        seen, out = set(), []
        for t, code in enumerate(codes):
            for key in self._buckets.get((dim, t, code), ()):
                if key not in seen:
                    seen.add(key)
                    out.append(key)
        self.stats["candidates"] += len(out)
        return out

    def rebuild(self) -> None:
        """Rehash every resident key from its stored centroid (bucket
        rehash: e.g. after deserializing an index or tuning planes).  A
        rebuild with unchanged planes reproduces the buckets exactly —
        pinned by the differential suite's rehash edge case."""
        items = [(k, c) for k, (_, _, c) in self._sigs.items()]
        self._buckets.clear()
        self._sigs.clear()
        for key, c in items:
            self.add(key, c)
        self.stats["rehashes"] += 1

    def __len__(self) -> int:
        return len(self._sigs)

    @property
    def mean_candidates(self) -> float:
        """Average candidate-set size per lookup — the determinist probe
        the scaling bench asserts sub-linearity on."""
        n = self.stats["lookups"]
        return self.stats["candidates"] / n if n else 0.0


_INDEXES = {
    "scan": ScanIndex,
    "lsh": LshIndex,
}


def make_index(spec: Union[str, CentroidIndex, None],
               **kw) -> CentroidIndex:
    """Resolve an index name (``"scan"`` / ``"lsh"``) or pass an instance
    through; ``kw`` goes to the named constructor."""
    if spec is None:
        return ScanIndex()
    if isinstance(spec, str):
        if spec not in _INDEXES:
            raise ValueError(f"unknown cache index {spec!r}; "
                             f"have {sorted(_INDEXES)}")
        return _INDEXES[spec](**kw)
    return spec
