"""Admission & launch policies — WHEN work enters the engine, in one place.

PR 4 made packed ticks bitwise-exact but left launch order naive: a group
launches the moment it is full, has waited ``max_wait_ticks``, or is under
deadline pressure, regardless of what that does to pack shape.  Under
staggered arrivals that eagerness is exactly wrong — branch rows go out
padded to the static width N while compatible requests sit in the queue,
so ``summary()['pad_waste']`` is pure overhead and every sub-full group
opens a fresh pack bucket (one more denoiser launch per tick).  "Reusing
Computation in Text-to-Image Diffusion for Efficient Generation of Image
Sets" (arXiv 2508.21032) makes the same observation for cross-query
reuse: the wins only compound when admission is batch-aware.

This module concentrates those decisions behind two small interfaces so
the scheduler and the trunk cache stay mechanism, not policy:

* :class:`LaunchPolicy` — which *open* groups launch this tick, and in
  what order.  :class:`EagerPolicy` is the PR-4 behavior, kept as the
  conformance oracle; :class:`PadAwarePolicy` delays sub-full launches up
  to a deadline-safe hold window and orders releases so rows fill
  *existing* :class:`~repro.serving.packing.PackKey` buckets before
  opening new ones.
* :class:`CacheAdmission` — which completed trunks a
  :class:`~repro.serving.trunk_cache.TrunkCache` stores, and which entry
  it evicts first.  :class:`AdmitAll` is the PR-3 behavior (store
  everything, evict LRU); :class:`PopularityAdmission` only stores trunks
  whose quantized-centroid popularity count has crossed a threshold, and
  evicts cold entries first — a one-hit-wonder filter, the same shape as
  TinyLFU-style admission in front of an LRU.

Policies see the scheduler only through :class:`LaunchContext` (and the
cache only through quantized keys), so they are testable in isolation and
a new policy cannot reach into engine state.

Invariants every launch policy must preserve (enforced by
``tests/test_scheduler_fuzz.py`` and the conformance equivalence case):

* conservation — a policy chooses *when*, never *whether*: every open
  group must eventually launch once its hold budget or deadline window is
  exhausted;
* deadline safety — a hold may never cause a deadline miss: holding is
  only allowed while ``earliest_deadline > now + deadline_slack +
  ticks_to_finish`` (the conservative segment count a group needs to
  finish, assuming the virtual-time convention of ~1 ``now`` unit per
  tick — with a wall clock the eager urgency rule still backstops);
* NFE accounting is policy-invariant — launching later can merge arrivals
  into fuller groups (that is the point: fewer padded rows, fewer
  buckets, and never *more* NFE than eager), but the per-group accounting
  rules are identical, so with equal group compositions the completions
  are bitwise identical to eager.
"""
from __future__ import annotations

import math
from typing import (Any, Callable, Dict, FrozenSet, List, NamedTuple,
                    Optional, Protocol, Sequence, Tuple, Union,
                    runtime_checkable)

from repro.serving.packing import PackKey

# -- QoS classes -------------------------------------------------------------
#
# Two service classes ride every request through admission, grouping,
# launch ordering, advance selection and the stats: ``interactive``
# (latency-sensitive, usually deadlined) outranks ``batch`` (throughput
# traffic that must not starve — the WFQ weights and the scheduler's
# starvation bound guarantee that).  Rank 0 is the most urgent.

QOS_RANK: Dict[str, int] = {"interactive": 0, "batch": 1}
DEFAULT_QOS = "interactive"

# Quality tiers are the *step-budget* axis, orthogonal to QoS (urgency):
# a request's tier names the NFE budget its group runs at
# (``RequestScheduler(tiers=...)`` maps names to total step counts —
# draft/standard/premium by default).  Tiers are a grouping compartment
# like QoS but NOT a pack-compatibility axis: per-row DDIM grids let a
# draft group share a launch with premium traffic whenever their segment
# lengths line up (see ``serving.packing.pack_grid``).
DEFAULT_TIER = "standard"


def qos_rank(g) -> int:
    """Launch-order rank of a group/request's QoS class (duck-typed on
    ``.qos``; unknown or missing classes sort last)."""
    return QOS_RANK.get(getattr(g, "qos", DEFAULT_QOS), len(QOS_RANK))


class LaunchContext(NamedTuple):
    """Read-only tick snapshot a :class:`LaunchPolicy` decides from.

    ``signature_of`` maps an *open* group to the :class:`PackKey` it would
    occupy if launched this tick (the scheduler computes it from the
    group's would-be beta bucket AND its own shape/sampler — under a
    hetero mix the pad-aware bucket-fill release therefore reasons
    per-bucket: a thumbnail group only rides an in-flight thumbnail
    launch, never a hi-res one); ``inflight_signatures`` are the buckets
    the already-in-flight groups occupy this tick — a launch whose
    signature is in that set rides an existing launch for free.
    ``ticks_to_finish`` is the conservative number of ticks a freshly
    launched group needs to complete (``ceil(T / slice_steps) + 1``, the
    fork boundary can cost one extra segment; under mixed tiers the
    scheduler reports the max over the step budgets present, so a hold is
    deadline-safe for every tier).
    """
    now: float
    tick: int
    group_size: int
    max_wait_ticks: int
    deadline_slack: float
    ticks_to_finish: int
    inflight_signatures: FrozenSet[PackKey]
    signature_of: Callable[[Any], PackKey]
    # EWMA of arrivals per tick (the scheduler's estimate of the recent
    # arrival process) — what AdaptivePadAwarePolicy sizes holds from
    arrival_rate: float = 0.0


# -- per-group predicates (shared by every policy) ---------------------------

def is_full(g, ctx: LaunchContext) -> bool:
    return len(g.members) >= ctx.group_size


def wait_ticks(g, ctx: LaunchContext) -> int:
    return ctx.tick - g.created_tick


def is_urgent(g, ctx: LaunchContext) -> bool:
    """The eager deadline trigger: already inside the slack window."""
    return g.earliest_deadline() <= ctx.now + ctx.deadline_slack


def deadline_safe_to_hold(g, ctx: LaunchContext) -> bool:
    """A hold is safe iff the group can still launch next tick and finish
    before its earliest deadline (1 tick per ``now`` unit)."""
    return (g.earliest_deadline()
            > ctx.now + ctx.deadline_slack + ctx.ticks_to_finish)


@runtime_checkable
class LaunchPolicy(Protocol):
    """Which open groups launch this tick, in launch order."""

    name: str

    def launches(self, open_groups: Sequence[Any],
                 ctx: LaunchContext) -> List[Any]:
        ...


class EagerPolicy:
    """PR-4 behavior, kept as the oracle: launch the moment a group is
    full, has waited ``max_wait_ticks``, or is under deadline pressure —
    in open-group (creation) order."""

    name = "eager"

    def launches(self, open_groups: Sequence[Any],
                 ctx: LaunchContext) -> List[Any]:
        return [g for g in open_groups
                if is_full(g, ctx)
                or wait_ticks(g, ctx) >= ctx.max_wait_ticks
                or is_urgent(g, ctx)]


class PadAwarePolicy:
    """Hold sub-full groups, fill existing pack buckets first.

    Relative to :class:`EagerPolicy`, only the ``max_wait_ticks`` trigger
    changes — full and deadline-urgent groups launch identically.  A
    sub-full group that has exhausted ``max_wait_ticks`` is *held* for up
    to ``hold_ticks`` extra ticks so late theme-mates can still join (the
    rows it would otherwise pad), unless one of three releases fires
    first:

    * **deadline-unsafe** — holding one more tick could miss the earliest
      member deadline (see :func:`deadline_safe_to_hold`); launch now;
    * **bucket fill** — the group's would-be :class:`PackKey` matches a
      bucket the in-flight groups already occupy this tick, so launching
      adds rows to an existing denoiser launch instead of opening a new
      one; holding buys nothing on the launch axis, so release;
    * **hold expiry** — ``wait_ticks >= max_wait_ticks + hold_ticks``.

    Returned launch order: full / urgent groups first (they were never
    held), then bucket-filling releases, then expiry releases — existing
    buckets fill before new ones open.
    """

    def __init__(self, hold_ticks: int = 2):
        if hold_ticks < 0:
            raise ValueError(f"hold_ticks must be >= 0, got {hold_ticks}")
        self.hold_ticks = hold_ticks

    name = "pad_aware"

    def _hold_budget(self, g, ctx: LaunchContext) -> int:
        """Extra ticks this group may be held past ``max_wait_ticks`` —
        the fixed window here; :class:`AdaptivePadAwarePolicy` overrides
        it with an arrival-process estimate."""
        return self.hold_ticks

    def launches(self, open_groups: Sequence[Any],
                 ctx: LaunchContext) -> List[Any]:
        now, fills, expired = [], [], []
        for g in open_groups:
            if is_full(g, ctx) or is_urgent(g, ctx):
                now.append(g)
            elif wait_ticks(g, ctx) >= ctx.max_wait_ticks:
                if not deadline_safe_to_hold(g, ctx):
                    now.append(g)
                elif ctx.signature_of(g) in ctx.inflight_signatures:
                    fills.append(g)
                elif (wait_ticks(g, ctx)
                      >= ctx.max_wait_ticks + self._hold_budget(g, ctx)):
                    expired.append(g)
        return now + fills + expired


class AdaptivePadAwarePolicy(PadAwarePolicy):
    """Pad-aware holds sized by the *recent arrival process* instead of a
    fixed window (the PR-5 carry-over lever).

    A hold only pays off if arrivals are likely to fill the held rows
    before it expires, so the budget is the expected ticks until
    ``group_size - members`` more requests arrive, estimated from the
    scheduler's arrival-rate EWMA (``LaunchContext.arrival_rate``), and
    capped at ``hold_max``:

    * rate below ``min_rate`` — arrivals have dried up; the fill
      probability within any reasonable window is negligible, so the
      budget is 0 and the group launches at its eager point (a fixed
      window would hold it for nothing, paying pure latency);
    * rate ``r`` — budget ``min(hold_max, ceil(need / r))``: a brisk
      stream earns only the short hold it needs, a trickle earns the cap.

    Every release rule (deadline safety, bucket fill, expiry) is
    inherited — only the expiry budget adapts.
    """

    name = "adaptive"

    def __init__(self, hold_max: int = 4, min_rate: float = 0.25):
        super().__init__(hold_ticks=hold_max)
        if min_rate <= 0:
            raise ValueError(f"min_rate must be > 0, got {min_rate}")
        self.min_rate = min_rate

    def _hold_budget(self, g, ctx: LaunchContext) -> int:
        need = max(ctx.group_size - len(g.members), 1)
        if ctx.arrival_rate < self.min_rate:
            return 0
        return min(self.hold_ticks,
                   int(math.ceil(need / ctx.arrival_rate)))


_LAUNCH_POLICIES: Dict[str, Callable[[], LaunchPolicy]] = {
    "eager": EagerPolicy,
    "pad_aware": PadAwarePolicy,
    "adaptive": AdaptivePadAwarePolicy,
}


def make_launch_policy(spec: Union[str, LaunchPolicy, None],
                       **kw) -> LaunchPolicy:
    """Resolve a policy name (``"eager"`` / ``"pad_aware"``) or pass an
    instance through; ``kw`` goes to the named constructor."""
    if spec is None:
        return EagerPolicy()
    if isinstance(spec, str):
        if spec not in _LAUNCH_POLICIES:
            raise ValueError(f"unknown launch policy {spec!r}; "
                             f"have {sorted(_LAUNCH_POLICIES)}")
        return _LAUNCH_POLICIES[spec](**kw)
    return spec


# -- launch-order comparators ------------------------------------------------
#
# WHICH in-flight/open groups go first — the pluggable priority hook for
# ``max_groups_per_tick`` selection (carry-over from the ROADMAP: the
# PR-5 tick loop hard-coded EDF).  An order is a plain key function over
# duck-typed groups (``qos`` / ``earliest_deadline()`` / ``gid``); the
# scheduler sorts its advance candidates with it and the WFQ/preemption
# selector consumes candidates in that order within each class.

LaunchOrder = Callable[[Any], Tuple]


def order_fifo(g) -> Tuple:
    """Strict arrival order (group creation), QoS- and deadline-blind —
    the overload baseline that lets batch backlogs starve interactive."""
    return (g.gid,)


def order_edf(g) -> Tuple:
    """Earliest deadline first, ties by creation — the PR-5 behavior."""
    return (g.earliest_deadline(), g.gid)


def order_qos_edf(g) -> Tuple:
    """(qos, deadline) — the default: interactive outranks batch, EDF
    within a class.  With a single QoS class this is exactly
    :func:`order_edf`, which is what keeps the conformance goldens
    byte-stable."""
    return (qos_rank(g), g.earliest_deadline(), g.gid)


_LAUNCH_ORDERS: Dict[str, LaunchOrder] = {
    "fifo": order_fifo,
    "edf": order_edf,
    "qos_edf": order_qos_edf,
}


def make_launch_order(spec: Union[str, LaunchOrder, None]) -> LaunchOrder:
    """Resolve an order name (``"fifo"`` / ``"edf"`` / ``"qos_edf"``) or
    pass a key callable through (it receives a group, returns a sort
    key)."""
    if spec is None:
        return order_qos_edf
    if isinstance(spec, str):
        if spec not in _LAUNCH_ORDERS:
            raise ValueError(f"unknown launch order {spec!r}; "
                             f"have {sorted(_LAUNCH_ORDERS)}")
        return _LAUNCH_ORDERS[spec]
    return spec


# -- request admission (overload control) ------------------------------------

class AdmissionContext(NamedTuple):
    """Read-only saturation snapshot an :class:`AdmissionPolicy` decides
    from, one instance per arriving request.  ``backlog_ticks`` is the
    scheduler's conservative drain-time estimate for the work already in
    the system (open + in-flight groups over the per-tick advance
    capacity); ``arrival_rate`` is the arrivals-per-tick EWMA."""
    now: float
    qos: str
    deadline: Optional[float]
    backlog_ticks: float
    ticks_to_finish: int
    arrival_rate: float


ADMIT, SHED, DEGRADE = "admit", "shed", "degrade"


@runtime_checkable
class AdmissionPolicy(Protocol):
    """Per-request admission verdict: ``"admit"`` (serve normally),
    ``"shed"`` (reject now, accounted — a ``Completed`` record with
    ``status="shed"``), or ``"degrade"`` (admit at draft quality: the
    request is downgraded to the scheduler's ``degrade_tier`` step
    budget — fewer total sampler steps, and the degraded group still
    CO-PACKS with full-quality launches via per-row grids instead of
    being forced into its own beta compartment — completions carry
    ``status="degraded"``).
    """

    name: str

    def decide(self, ctx: AdmissionContext) -> str: ...


class AdmitAllRequests:
    """No overload control (the pre-QoS behavior): everything is served,
    however deep the backlog."""

    name = "admit_all"

    def decide(self, ctx: AdmissionContext) -> str:
        return ADMIT


class SaturationAdmission:
    """Shed (or degrade) past a saturation estimate.

    A request is refused normal service once the backlog exceeds
    ``horizon_ticks`` of drain time — at that depth its own completion
    would land ``backlog`` ticks out, so serving it at full quality only
    lengthens everyone's queue (the goodput-collapse regime graceful
    degradation exists to avoid).  ``interactive`` requests get
    ``interactive_headroom`` × the horizon before they shed: the classes
    the queue exists to protect are the last to be turned away.

    ``mode`` picks the refusal: ``"shed"`` rejects outright (cheapest,
    an accounted ``status="shed"`` completion), ``"degrade"`` admits at
    draft NFE (a tier downgrade to the scheduler's ``degrade_tier``
    step budget; the degraded group co-packs with standard launches,
    ``status="degraded"``).
    """

    name = "saturation"

    def __init__(self, horizon_ticks: float = 8.0, mode: str = SHED,
                 interactive_headroom: float = 2.0):
        if horizon_ticks <= 0:
            raise ValueError(
                f"horizon_ticks must be > 0, got {horizon_ticks}")
        if mode not in (SHED, DEGRADE):
            raise ValueError(f"mode must be 'shed' or 'degrade', "
                             f"got {mode!r}")
        if interactive_headroom < 1.0:
            raise ValueError(f"interactive_headroom must be >= 1, "
                             f"got {interactive_headroom}")
        self.horizon_ticks = horizon_ticks
        self.mode = mode
        self.interactive_headroom = interactive_headroom

    def decide(self, ctx: AdmissionContext) -> str:
        limit = self.horizon_ticks
        if QOS_RANK.get(ctx.qos, len(QOS_RANK)) == 0:
            limit *= self.interactive_headroom
        return ADMIT if ctx.backlog_ticks <= limit else self.mode


_ADMISSION_POLICIES: Dict[str, Callable[..., AdmissionPolicy]] = {
    "admit_all": AdmitAllRequests,
    "shed": lambda **kw: SaturationAdmission(mode=SHED, **kw),
    "degrade": lambda **kw: SaturationAdmission(mode=DEGRADE, **kw),
}


def make_admission_policy(spec: Union[str, AdmissionPolicy, None],
                          **kw) -> AdmissionPolicy:
    """Resolve an admission name (``"admit_all"`` / ``"shed"`` /
    ``"degrade"``) or pass an instance through; ``kw`` goes to the named
    constructor."""
    if spec is None:
        return AdmitAllRequests()
    if isinstance(spec, str):
        if spec not in _ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {spec!r}; "
                             f"have {sorted(_ADMISSION_POLICIES)}")
        return _ADMISSION_POLICIES[spec](**kw)
    return spec


# -- trunk-cache admission ---------------------------------------------------

@runtime_checkable
class CacheAdmission(Protocol):
    """Store/evict policy for :class:`~repro.serving.trunk_cache.TrunkCache`.

    ``on_lookup`` is called once per cache lookup with the requester's
    quantized key — BOTH the exact-key path and the similarity-search
    path, hit or miss — so popularity counts measure *demand*, not
    residency.  ``admit`` gates ``insert``; ``victim`` picks which key
    the pressured tier demotes or evicts first (``keys`` iterates that
    tier's residents in LRU → MRU order; ``tier`` names it — ``"hbm"``
    victims spill to the host tier when one is configured, ``"host"``
    victims leave the cache, so a tier-aware policy can protect
    hard-to-recompute entries from the terminal eviction while letting
    them spill freely).
    """

    name: str

    def on_lookup(self, key: Tuple) -> None: ...

    def admit(self, key: Tuple) -> bool: ...

    def victim(self, keys: Sequence[Tuple],
               tier: str = "") -> Optional[Tuple]: ...


class AdmitAll:
    """PR-3 behavior: store every completed trunk, evict plain LRU —
    tier-blind: the coldest resident of whichever tier is under pressure
    spills/evicts first."""

    name = "always"

    def on_lookup(self, key: Tuple) -> None:
        pass

    def admit(self, key: Tuple) -> bool:
        return True

    def victim(self, keys: Sequence[Tuple],
               tier: str = "") -> Optional[Tuple]:
        for k in keys:                      # first = least recently used
            return k
        return None


class PopularityAdmission:
    """Only store trunks whose quantized-centroid key has been *asked for*
    at least ``threshold`` times; evict cold entries first.

    The count is demand-side: every :meth:`TrunkCache.lookup` ticks the
    requester's key (the satellite fix routes the exact-key hit path
    through this counter too), so a theme must recur before its trunk
    earns bytes — one-hit wonders never displace hot entries.  Eviction
    inverts the same signal: the victim is the stored key with the lowest
    popularity, ties broken LRU-first.  Counts survive eviction AND tier
    moves (they measure the *stream*, not the cache), so a trunk that
    spilled cold and reheated is promoted on its popularity, not reset —
    the ``tier`` kwarg is accepted for the protocol but the demand signal
    is deliberately tier-blind.  Bounded by ``max_keys`` with
    drop-coldest-half pruning so a long-lived server cannot grow counter
    state without bound.
    """

    def __init__(self, threshold: int = 2, max_keys: int = 65_536):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.max_keys = max_keys
        self.counts: Dict[Tuple, int] = {}

    name = "popularity"

    def on_lookup(self, key: Tuple) -> None:
        self.counts[key] = self.counts.get(key, 0) + 1
        if len(self.counts) > self.max_keys:
            keep = sorted(self.counts.items(), key=lambda kv: -kv[1])
            self.counts = dict(keep[:self.max_keys // 2])

    def admit(self, key: Tuple) -> bool:
        return self.counts.get(key, 0) >= self.threshold

    def victim(self, keys: Sequence[Tuple],
               tier: str = "") -> Optional[Tuple]:
        best, best_count = None, None
        for k in keys:                      # LRU -> MRU: ties stay LRU
            c = self.counts.get(k, 0)
            if best is None or c < best_count:
                best, best_count = k, c
        return best


_CACHE_ADMISSIONS: Dict[str, Callable[..., CacheAdmission]] = {
    "always": AdmitAll,
    "popularity": PopularityAdmission,
}


def make_cache_admission(spec: Union[str, CacheAdmission, None],
                         **kw) -> CacheAdmission:
    """Resolve an admission name (``"always"`` / ``"popularity"``) or pass
    an instance through; ``kw`` goes to the named constructor."""
    if spec is None:
        return AdmitAll()
    if isinstance(spec, str):
        if spec not in _CACHE_ADMISSIONS:
            raise ValueError(f"unknown cache admission {spec!r}; "
                             f"have {sorted(_CACHE_ADMISSIONS)}")
        return _CACHE_ADMISSIONS[spec](**kw)
    return spec
