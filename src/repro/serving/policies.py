"""Admission & launch policies — WHEN work enters the engine, in one place.

PR 4 made packed ticks bitwise-exact but left launch order naive: a group
launches the moment it is full, has waited ``max_wait_ticks``, or is under
deadline pressure, regardless of what that does to pack shape.  Under
staggered arrivals that eagerness is exactly wrong — branch rows go out
padded to the static width N while compatible requests sit in the queue,
so ``summary()['pad_waste']`` is pure overhead and every sub-full group
opens a fresh pack bucket (one more denoiser launch per tick).  "Reusing
Computation in Text-to-Image Diffusion for Efficient Generation of Image
Sets" (arXiv 2508.21032) makes the same observation for cross-query
reuse: the wins only compound when admission is batch-aware.

This module concentrates those decisions behind two small interfaces so
the scheduler and the trunk cache stay mechanism, not policy:

* :class:`LaunchPolicy` — which *open* groups launch this tick, and in
  what order.  :class:`EagerPolicy` is the PR-4 behavior, kept as the
  conformance oracle; :class:`PadAwarePolicy` delays sub-full launches up
  to a deadline-safe hold window and orders releases so rows fill
  *existing* :class:`~repro.serving.packing.PackKey` buckets before
  opening new ones.
* :class:`CacheAdmission` — which completed trunks a
  :class:`~repro.serving.trunk_cache.TrunkCache` stores, and which entry
  it evicts first.  :class:`AdmitAll` is the PR-3 behavior (store
  everything, evict LRU); :class:`PopularityAdmission` only stores trunks
  whose quantized-centroid popularity count has crossed a threshold, and
  evicts cold entries first — a one-hit-wonder filter, the same shape as
  TinyLFU-style admission in front of an LRU.

Policies see the scheduler only through :class:`LaunchContext` (and the
cache only through quantized keys), so they are testable in isolation and
a new policy cannot reach into engine state.

Invariants every launch policy must preserve (enforced by
``tests/test_scheduler_fuzz.py`` and the conformance equivalence case):

* conservation — a policy chooses *when*, never *whether*: every open
  group must eventually launch once its hold budget or deadline window is
  exhausted;
* deadline safety — a hold may never cause a deadline miss: holding is
  only allowed while ``earliest_deadline > now + deadline_slack +
  ticks_to_finish`` (the conservative segment count a group needs to
  finish, assuming the virtual-time convention of ~1 ``now`` unit per
  tick — with a wall clock the eager urgency rule still backstops);
* NFE accounting is policy-invariant — launching later can merge arrivals
  into fuller groups (that is the point: fewer padded rows, fewer
  buckets, and never *more* NFE than eager), but the per-group accounting
  rules are identical, so with equal group compositions the completions
  are bitwise identical to eager.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, FrozenSet, List, NamedTuple,
                    Optional, Protocol, Sequence, Tuple, Union,
                    runtime_checkable)

from repro.serving.packing import PackKey


class LaunchContext(NamedTuple):
    """Read-only tick snapshot a :class:`LaunchPolicy` decides from.

    ``signature_of`` maps an *open* group to the :class:`PackKey` it would
    occupy if launched this tick (the scheduler computes it from the
    group's would-be beta bucket); ``inflight_signatures`` are the buckets
    the already-in-flight groups occupy this tick — a launch whose
    signature is in that set rides an existing launch for free.
    ``ticks_to_finish`` is the conservative number of ticks a freshly
    launched group needs to complete (``ceil(T / slice_steps) + 1``, the
    fork boundary can cost one extra segment).
    """
    now: float
    tick: int
    group_size: int
    max_wait_ticks: int
    deadline_slack: float
    ticks_to_finish: int
    inflight_signatures: FrozenSet[PackKey]
    signature_of: Callable[[Any], PackKey]


# -- per-group predicates (shared by every policy) ---------------------------

def is_full(g, ctx: LaunchContext) -> bool:
    return len(g.members) >= ctx.group_size


def wait_ticks(g, ctx: LaunchContext) -> int:
    return ctx.tick - g.created_tick


def is_urgent(g, ctx: LaunchContext) -> bool:
    """The eager deadline trigger: already inside the slack window."""
    return g.earliest_deadline() <= ctx.now + ctx.deadline_slack


def deadline_safe_to_hold(g, ctx: LaunchContext) -> bool:
    """A hold is safe iff the group can still launch next tick and finish
    before its earliest deadline (1 tick per ``now`` unit)."""
    return (g.earliest_deadline()
            > ctx.now + ctx.deadline_slack + ctx.ticks_to_finish)


@runtime_checkable
class LaunchPolicy(Protocol):
    """Which open groups launch this tick, in launch order."""

    name: str

    def launches(self, open_groups: Sequence[Any],
                 ctx: LaunchContext) -> List[Any]:
        ...


class EagerPolicy:
    """PR-4 behavior, kept as the oracle: launch the moment a group is
    full, has waited ``max_wait_ticks``, or is under deadline pressure —
    in open-group (creation) order."""

    name = "eager"

    def launches(self, open_groups: Sequence[Any],
                 ctx: LaunchContext) -> List[Any]:
        return [g for g in open_groups
                if is_full(g, ctx)
                or wait_ticks(g, ctx) >= ctx.max_wait_ticks
                or is_urgent(g, ctx)]


class PadAwarePolicy:
    """Hold sub-full groups, fill existing pack buckets first.

    Relative to :class:`EagerPolicy`, only the ``max_wait_ticks`` trigger
    changes — full and deadline-urgent groups launch identically.  A
    sub-full group that has exhausted ``max_wait_ticks`` is *held* for up
    to ``hold_ticks`` extra ticks so late theme-mates can still join (the
    rows it would otherwise pad), unless one of three releases fires
    first:

    * **deadline-unsafe** — holding one more tick could miss the earliest
      member deadline (see :func:`deadline_safe_to_hold`); launch now;
    * **bucket fill** — the group's would-be :class:`PackKey` matches a
      bucket the in-flight groups already occupy this tick, so launching
      adds rows to an existing denoiser launch instead of opening a new
      one; holding buys nothing on the launch axis, so release;
    * **hold expiry** — ``wait_ticks >= max_wait_ticks + hold_ticks``.

    Returned launch order: full / urgent groups first (they were never
    held), then bucket-filling releases, then expiry releases — existing
    buckets fill before new ones open.
    """

    def __init__(self, hold_ticks: int = 2):
        if hold_ticks < 0:
            raise ValueError(f"hold_ticks must be >= 0, got {hold_ticks}")
        self.hold_ticks = hold_ticks

    name = "pad_aware"

    def launches(self, open_groups: Sequence[Any],
                 ctx: LaunchContext) -> List[Any]:
        now, fills, expired = [], [], []
        for g in open_groups:
            if is_full(g, ctx) or is_urgent(g, ctx):
                now.append(g)
            elif wait_ticks(g, ctx) >= ctx.max_wait_ticks:
                if not deadline_safe_to_hold(g, ctx):
                    now.append(g)
                elif ctx.signature_of(g) in ctx.inflight_signatures:
                    fills.append(g)
                elif (wait_ticks(g, ctx)
                      >= ctx.max_wait_ticks + self.hold_ticks):
                    expired.append(g)
        return now + fills + expired


_LAUNCH_POLICIES: Dict[str, Callable[[], LaunchPolicy]] = {
    "eager": EagerPolicy,
    "pad_aware": PadAwarePolicy,
}


def make_launch_policy(spec: Union[str, LaunchPolicy, None],
                       **kw) -> LaunchPolicy:
    """Resolve a policy name (``"eager"`` / ``"pad_aware"``) or pass an
    instance through; ``kw`` goes to the named constructor."""
    if spec is None:
        return EagerPolicy()
    if isinstance(spec, str):
        if spec not in _LAUNCH_POLICIES:
            raise ValueError(f"unknown launch policy {spec!r}; "
                             f"have {sorted(_LAUNCH_POLICIES)}")
        return _LAUNCH_POLICIES[spec](**kw)
    return spec


# -- trunk-cache admission ---------------------------------------------------

@runtime_checkable
class CacheAdmission(Protocol):
    """Store/evict policy for :class:`~repro.serving.trunk_cache.TrunkCache`.

    ``on_lookup`` is called once per cache lookup with the requester's
    quantized key — BOTH the exact-key path and the cosine-scan path, hit
    or miss — so popularity counts measure *demand*, not residency.
    ``admit`` gates ``insert``; ``victim`` picks which key the byte budget
    evicts first (``keys`` iterates in LRU → MRU order).
    """

    name: str

    def on_lookup(self, key: Tuple) -> None: ...

    def admit(self, key: Tuple) -> bool: ...

    def victim(self, keys: Sequence[Tuple]) -> Optional[Tuple]: ...


class AdmitAll:
    """PR-3 behavior: store every completed trunk, evict plain LRU."""

    name = "always"

    def on_lookup(self, key: Tuple) -> None:
        pass

    def admit(self, key: Tuple) -> bool:
        return True

    def victim(self, keys: Sequence[Tuple]) -> Optional[Tuple]:
        for k in keys:                      # first = least recently used
            return k
        return None


class PopularityAdmission:
    """Only store trunks whose quantized-centroid key has been *asked for*
    at least ``threshold`` times; evict cold entries first.

    The count is demand-side: every :meth:`TrunkCache.lookup` ticks the
    requester's key (the satellite fix routes the exact-key hit path
    through this counter too), so a theme must recur before its trunk
    earns bytes — one-hit wonders never displace hot entries.  Eviction
    inverts the same signal: the victim is the stored key with the lowest
    popularity, ties broken LRU-first.  Counts survive eviction (they
    measure the *stream*, not the cache), bounded by ``max_keys`` with
    drop-coldest-half pruning so a long-lived server cannot grow counter
    state without bound.
    """

    def __init__(self, threshold: int = 2, max_keys: int = 65_536):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.max_keys = max_keys
        self.counts: Dict[Tuple, int] = {}

    name = "popularity"

    def on_lookup(self, key: Tuple) -> None:
        self.counts[key] = self.counts.get(key, 0) + 1
        if len(self.counts) > self.max_keys:
            keep = sorted(self.counts.items(), key=lambda kv: -kv[1])
            self.counts = dict(keep[:self.max_keys // 2])

    def admit(self, key: Tuple) -> bool:
        return self.counts.get(key, 0) >= self.threshold

    def victim(self, keys: Sequence[Tuple]) -> Optional[Tuple]:
        best, best_count = None, None
        for k in keys:                      # LRU -> MRU: ties stay LRU
            c = self.counts.get(k, 0)
            if best is None or c < best_count:
                best, best_count = k, c
        return best


_CACHE_ADMISSIONS: Dict[str, Callable[..., CacheAdmission]] = {
    "always": AdmitAll,
    "popularity": PopularityAdmission,
}


def make_cache_admission(spec: Union[str, CacheAdmission, None],
                         **kw) -> CacheAdmission:
    """Resolve an admission name (``"always"`` / ``"popularity"``) or pass
    an instance through; ``kw`` goes to the named constructor."""
    if spec is None:
        return AdmitAll()
    if isinstance(spec, str):
        if spec not in _CACHE_ADMISSIONS:
            raise ValueError(f"unknown cache admission {spec!r}; "
                             f"have {sorted(_CACHE_ADMISSIONS)}")
        return _CACHE_ADMISSIONS[spec](**kw)
    return spec
