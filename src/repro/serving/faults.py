"""Deterministic fault-injection harness for the serving tier.

A production scheduler's invariants are only as good as the failure
modes they survive: segment launches fail (driver resets, preempted
device queues), cache payloads rot (bitflips, truncated spills), and
whole ticks stall (GC pauses, noisy neighbours).  This module injects
those faults *deterministically* so the fuzz suite can assert the
recovery contract — every injected fault is either **recovered** (a
retried launch produces bitwise-identical results, a corrupted cache
entry is detected and recomputed exactly) or **surfaced** as an
accounted shed; never a silent drop.

:class:`FaultPlan` is the single knob surface.  Each fault kind draws
from its own seeded ``RandomState`` stream, advanced once per *query*
(one query per pack launch, per cache hit, per tick), so a plan replays
identically on the same trace regardless of which other kinds are
enabled — the streams never interleave.  The scheduler and
:class:`~repro.serving.trunk_cache.TrunkCache` consult the plan at their
fault points:

* ``launch_fails()``  — queried once per segment launch (one per pack
  bucket, or per group on the per-group oracle path).  On injection the
  launch is skipped — the carry is untouched, so the retry (scheduled
  with exponential backoff, bounded by ``RequestScheduler(max_retries)``)
  re-runs the *same* computation and the completion is bitwise-identical
  to the fault-free run, just later.  Retry exhaustion sheds the group:
  members complete with ``status="shed"`` and the spent NFE moves to the
  ``nfe_wasted`` ledger.
* ``cache_miss()``    — queried once per would-be trunk-cache hit;
  injection forces a miss (entry retained).  Recovery is trivial: the
  group computes its own shared phase, which is the *exact* result.
* ``cache_corrupt()`` — queried once per would-be hit (after the forced
  -miss query); injection flips a byte of the stored latent.  The
  cache's always-on CRC integrity gate detects the damage, drops the
  entry (``stats['integrity_drops']``) and reports a miss — a corrupted
  trunk can never silently steer a trajectory.
* ``tick_stalls()``   — queried once per ``tick()``; injection turns the
  tick into a pure time advance (no admission, no launches, no
  segments).  Deadline machinery sees the lost time: stalled-away
  deadlines surface as urgent launches or ``rejected_expired``, never as
  unaccounted lateness.

``max_faults`` bounds the total injection count (the escape hatch for
``p=1.0`` worst-case plans that must still drain).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import numpy as np

KINDS = ("launch_fail", "cache_miss", "cache_corrupt", "tick_stall")

# CLI spec aliases (see FaultPlan.parse): short token -> dataclass field
_SPEC_KEYS = {"launch": "p_launch_fail", "miss": "p_cache_miss",
              "corrupt": "p_cache_corrupt", "stall": "p_tick_stall"}


def array_crc(x) -> int:
    """CRC32 of a payload's bytes — the trunk-cache integrity fingerprint
    (cheap at serving-cache entry sizes; any corruption model that flips
    stored bytes is caught).  ``x`` may be a single array or an arbitrary
    pytree (the AR-prefix payloads are (logits, kv-cache) trees): leaves
    are chained through one running CRC, so a single array hashes exactly
    as before and any leaf flip changes the fingerprint."""
    crc = 0
    for leaf in jax.tree.leaves(x):
        crc = zlib.crc32(
            np.ascontiguousarray(np.asarray(leaf)).tobytes(), crc)
    return crc


def corrupt_array(x):
    """Deterministically damage one byte of ``x`` (the injected
    corruption model): flip every bit of byte 0 of the first leaf.
    Returns a new array/pytree with the same structure whose CRC cannot
    match the original."""
    leaves, treedef = jax.tree.flatten(x)
    a = np.ascontiguousarray(np.asarray(leaves[0])).copy()
    raw = a.view(np.uint8).reshape(-1)
    raw[0] ^= 0xFF
    return jax.tree.unflatten(treedef, [a] + leaves[1:])


@dataclass
class FaultPlan:
    """Seeded, per-kind-streamed fault injectors (see module docstring).

    Probabilities are per *query*; ``injected``/``queries`` count per
    kind so a test can assert both that faults fired and that every
    firing was accounted downstream.
    """
    seed: int = 0
    p_launch_fail: float = 0.0
    p_cache_miss: float = 0.0
    p_cache_corrupt: float = 0.0
    p_tick_stall: float = 0.0
    max_faults: Optional[int] = None
    injected: Dict[str, int] = field(default_factory=dict)
    queries: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        for k in KINDS:
            p = getattr(self, f"p_{k}")
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"p_{k} must be in [0, 1], got {p}")
        # one independent stream per kind: a kind's Nth query draws the
        # same uniform no matter which other kinds are enabled
        self._rng = {k: np.random.RandomState(
            zlib.crc32(k.encode()) ^ (self.seed & 0x7FFFFFFF))
            for k in KINDS}
        self.injected = {k: 0 for k in KINDS}
        self.queries = {k: 0 for k in KINDS}

    # ------------------------------------------------------------------
    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def _fire(self, kind: str) -> bool:
        self.queries[kind] += 1
        p = getattr(self, f"p_{kind}")
        if p <= 0.0:
            return False
        if (self.max_faults is not None
                and self.total_injected >= self.max_faults):
            return False
        hit = bool(self._rng[kind].rand() < p)
        if hit:
            self.injected[kind] += 1
        return hit

    def launch_fails(self) -> bool:
        return self._fire("launch_fail")

    def cache_miss(self) -> bool:
        return self._fire("cache_miss")

    def cache_corrupt(self) -> bool:
        return self._fire("cache_corrupt")

    def tick_stalls(self) -> bool:
        return self._fire("tick_stall")

    # ------------------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from a CLI spec string, e.g.
        ``"launch=0.2,miss=0.1,corrupt=0.05,stall=0.1,seed=3,max=20"``
        (all tokens optional; see ``_SPEC_KEYS`` for the aliases)."""
        kw = {}
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            if "=" not in tok:
                raise ValueError(f"bad fault-plan token {tok!r} "
                                 f"(want key=value)")
            k, v = tok.split("=", 1)
            if k in _SPEC_KEYS:
                kw[_SPEC_KEYS[k]] = float(v)
            elif k == "seed":
                kw["seed"] = int(v)
            elif k == "max":
                kw["max_faults"] = int(v)
            else:
                raise ValueError(
                    f"unknown fault-plan key {k!r}; have "
                    f"{sorted(_SPEC_KEYS) + ['seed', 'max']}")
        return cls(**kw)
