"""SAGE diffusion serving engine (the paper's deployment surface).

Request lifecycle:
  submit(prompts) -> [queue] -> embed (text tower) -> semantic grouping
  (greedy cliques over the tau threshold graph) -> pad to the static group
  width N -> Alg. 1 shared sampling (jit per (K, N, T*) bucket) -> VAE
  decode -> responses + NFE accounting.

The sampling machinery lives in ``repro.serving.scheduler``: ``step()``
delegates to :meth:`RequestScheduler.run_batch`, the synchronous special
case of the continuous-batching tick loop (whole-phase segments, no
arrivals, no trunk cache).  For arrival-driven serving with cross-batch
trunk reuse, drive the scheduler directly — see
:meth:`SageServingEngine.streaming_scheduler` and
``examples/serve_shared.py --streaming``.

Adaptive branch point (paper §2.2 option): T* is chosen from each group's
own min pairwise similarity and snapped to a small bucket set so each
bucket compiles once (one packed sampler call per bucket — a singleton
group's pinned min-sim no longer drags other groups' buckets).

Edge semantics for grouping (which cosine similarities count as "similar
enough") are defined once in ``core.grouping.edge_mask`` — the
(tau_min, tau_max] convention — not re-encoded here.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.config import ModelConfig, SageConfig
from repro.config import replace as config_replace
from repro.core.schedule import Schedule, make_schedule
from repro.serving.scheduler import Completed, RequestScheduler
from repro.serving.telemetry import MetricsRegistry, Tracer
from repro.serving.trunk_cache import TrunkCache

__all__ = ["Completed", "SageServingEngine"]


class SageServingEngine:
    def __init__(self, model_cfg: ModelConfig, sage: SageConfig,
                 dit_params, text_params, text_cfg, vae_params=None,
                 sched: Optional[Schedule] = None, group_size: int = 4,
                 branch_buckets: Sequence[float] = (0.2, 0.3, 0.4),
                 seed: int = 0, attn_impl: Optional[str] = None,
                 step_impl: Optional[str] = None,
                 kernel_interpret: Optional[str] = None,
                 policy: str = "eager", tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        """attn_impl / step_impl / kernel_interpret override the kernel
        backend knobs of model_cfg / sage (see repro.kernels.dispatch):
        attn_impl="pallas" + step_impl="fused" runs the whole sampling hot
        path on the Pallas kernels.  ``policy`` is the launch policy
        (``serving.policies``) inherited by :meth:`streaming_scheduler`;
        the synchronous :meth:`step` path has no arrivals to hold for, so
        the policy only matters for streaming.  ``tracer``/``metrics``
        (``serving.telemetry``) are forwarded to the internal scheduler;
        a streaming scheduler wants its own (registry prefixes are
        claimed per scheduler) — pass them via
        :meth:`streaming_scheduler` kwargs instead."""
        if attn_impl is not None:
            model_cfg = config_replace(model_cfg, attn_impl=attn_impl)
        if kernel_interpret is not None:
            model_cfg = config_replace(model_cfg,
                                       kernel_interpret=kernel_interpret)
            sage = config_replace(sage, kernel_interpret=kernel_interpret)
        if step_impl is not None:
            sage = config_replace(sage, step_impl=step_impl)
        self.cfg = model_cfg
        self.sage = sage
        self.sched = sched or make_schedule(1000)
        self.dit_params = dit_params
        self.text_params = text_params
        self.text_cfg = text_cfg
        self.vae_params = vae_params
        self.group_size = group_size
        self.branch_buckets = branch_buckets
        self.seed = seed
        self.policy = policy
        self.queue: List[str] = []
        self.scheduler = RequestScheduler(
            model_cfg, sage, dit_params, text_params, text_cfg,
            vae_params=vae_params, sched=self.sched, group_size=group_size,
            branch_buckets=branch_buckets, policy=policy, seed=seed,
            tracer=tracer, metrics=metrics)

    # ------------------------------------------------------------------
    def submit(self, prompts: Sequence[str]) -> None:
        self.queue.extend(prompts)

    def step(self, max_batch: int = 32, adaptive: Optional[bool] = None
             ) -> List[Completed]:
        """Serve one engine iteration over up to max_batch queued prompts."""
        if not self.queue:
            return []
        prompts = self.queue[:max_batch]
        self.queue = self.queue[max_batch:]
        return self.scheduler.run_batch(prompts, adaptive=adaptive)

    def streaming_scheduler(self, slice_steps: int = 4,
                            max_wait_ticks: int = 2,
                            trunk_cache: Optional[TrunkCache] = None,
                            **kw) -> RequestScheduler:
        """A fresh continuous-batching scheduler over this engine's model
        (arrival-driven ticks + optional cross-batch trunk cache); the
        engine's own synchronous scheduler and stats are untouched.
        Heterogeneous-serving knobs (``tiers``, ``mix_samplers``,
        ``degrade_tier``, qos/admission, telemetry) forward through
        ``**kw`` — per-request shape/tier/sampler are then chosen at
        ``submit()`` time on the returned scheduler."""
        kw.setdefault("seed", self.seed)
        kw.setdefault("policy", self.policy)
        return RequestScheduler(
            self.cfg, self.sage, self.dit_params, self.text_params,
            self.text_cfg, vae_params=self.vae_params, sched=self.sched,
            group_size=self.group_size, branch_buckets=self.branch_buckets,
            slice_steps=slice_steps, max_wait_ticks=max_wait_ticks,
            trunk_cache=trunk_cache, **kw)

    @property
    def stats(self):
        return self.scheduler.stats

    @property
    def cost_saving(self) -> float:
        return self.scheduler.cost_saving
