"""SAGE diffusion serving engine (the paper's deployment surface).

Request lifecycle:
  submit(prompts) -> [queue] -> embed (text tower) -> semantic grouping
  (greedy cliques over the tau threshold graph) -> pad to the static group
  width N -> Alg. 1 shared sampling (jit per (K, N, T*) bucket) -> VAE
  decode -> responses + NFE accounting.

Adaptive branch point (paper §2.2 option): T* is chosen from the group's
min pairwise similarity and snapped to a small bucket set so each bucket
compiles once.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, SageConfig
from repro.config import replace as config_replace
from repro.core import grouping
from repro.core.schedule import Schedule, make_schedule
from repro.core.shared_sampling import shared_sample
from repro.models import dit, vae as vae_lib
from repro.models import text_encoder as te


@dataclass
class Completed:
    prompt: str
    image: np.ndarray
    group_id: int
    nfe_share: float


class SageServingEngine:
    def __init__(self, model_cfg: ModelConfig, sage: SageConfig,
                 dit_params, text_params, text_cfg, vae_params=None,
                 sched: Optional[Schedule] = None, group_size: int = 4,
                 branch_buckets: Sequence[float] = (0.2, 0.3, 0.4),
                 seed: int = 0, attn_impl: Optional[str] = None,
                 step_impl: Optional[str] = None,
                 kernel_interpret: Optional[str] = None):
        """attn_impl / step_impl / kernel_interpret override the kernel
        backend knobs of model_cfg / sage (see repro.kernels.dispatch):
        attn_impl="pallas" + step_impl="fused" runs the whole sampling hot
        path on the Pallas kernels."""
        if attn_impl is not None:
            model_cfg = config_replace(model_cfg, attn_impl=attn_impl)
        if kernel_interpret is not None:
            model_cfg = config_replace(model_cfg,
                                       kernel_interpret=kernel_interpret)
            sage = config_replace(sage, kernel_interpret=kernel_interpret)
        if step_impl is not None:
            sage = config_replace(sage, step_impl=step_impl)
        self.cfg = model_cfg
        self.sage = sage
        self.sched = sched or make_schedule(1000)
        self.dit_params = dit_params
        self.text_params = text_params
        self.text_cfg = text_cfg
        self.vae_params = vae_params
        self.group_size = group_size
        self.branch_buckets = branch_buckets
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[str] = []
        self.stats = {"nfe": 0.0, "nfe_independent": 0.0, "requests": 0}
        self._sample_cache: Dict[Any, Callable] = {}

    # ------------------------------------------------------------------
    def submit(self, prompts: Sequence[str]) -> None:
        self.queue.extend(prompts)

    def _embed(self, prompts: Sequence[str]):
        toks = te.tokenize(prompts, max_len=self.cfg.cond_len)
        feats, pooled = te.encode_text(self.text_params, self.text_cfg, toks)
        # project per-token features to the DiT cond width if needed
        if feats.shape[-1] != self.cfg.cond_dim:
            reps = -(-self.cfg.cond_dim // feats.shape[-1])
            feats = jnp.tile(feats, (1, 1, reps))[..., :self.cfg.cond_dim]
        return feats, np.asarray(pooled)

    def _sampler(self, K: int, N: int, beta: float, shared_uncond: bool):
        key = (K, N, round(beta, 2), shared_uncond)
        if key not in self._sample_cache:
            import dataclasses
            sage = dataclasses.replace(self.sage, share_ratio=beta,
                                       shared_uncond_cfg=shared_uncond)
            H = self.cfg.latent_size
            eps_fn = functools.partial(dit.forward, self.dit_params, self.cfg)

            @jax.jit
            def run(rng, cond, mask):
                null = jnp.zeros((self.cfg.cond_len, self.cfg.cond_dim))
                return shared_sample(
                    lambda z, t, c: eps_fn(z, t, c), self.sched, sage, rng,
                    cond, mask, null, (H, H, self.cfg.latent_channels))

            self._sample_cache[key] = run
        return self._sample_cache[key]

    # ------------------------------------------------------------------
    def step(self, max_batch: int = 32, adaptive: Optional[bool] = None
             ) -> List[Completed]:
        """Serve one engine iteration over up to max_batch queued prompts."""
        if not self.queue:
            return []
        prompts = self.queue[:max_batch]
        self.queue = self.queue[max_batch:]
        cond, pooled = self._embed(prompts)
        sim = grouping.similarity_matrix(pooled)
        groups = grouping.greedy_clique_groups(
            sim, self.sage.tau_min, group_max=self.group_size)
        idx, mask = grouping.pad_groups(groups, self.group_size)
        K, N = idx.shape

        adaptive = self.sage.adaptive_branch if adaptive is None else adaptive
        if adaptive:
            mins = []
            for g in groups:
                if len(g) == 1:
                    mins.append(1.0)
                else:
                    mins.append(min(sim[i, j] for i in g for j in g if i != j))
            beta_raw = float(np.clip(np.mean(mins), 0.0, 1.0)) * 0.5
            beta = min(self.branch_buckets, key=lambda b: abs(b - beta_raw))
        else:
            beta = self.sage.share_ratio

        cond_packed = jnp.asarray(cond)[idx.reshape(-1)].reshape(
            K, N, *cond.shape[1:])
        self.key, rng = jax.random.split(self.key)
        run = self._sampler(K, N, beta, self.sage.shared_uncond_cfg)
        out = run(rng, cond_packed, jnp.asarray(mask))

        latents = out["latents"]
        if self.vae_params is not None:
            imgs = vae_lib.decode(self.vae_params,
                                  latents.reshape(K * N,
                                                  *latents.shape[2:]))
            imgs = np.asarray(imgs).reshape(K, N, *imgs.shape[1:])
        else:
            imgs = np.asarray(latents)

        nfe = float(out["nfe"])
        indep = 2.0 * len(prompts) * self.sage.total_steps
        self.stats["nfe"] += nfe
        self.stats["nfe_independent"] += indep
        self.stats["requests"] += len(prompts)

        done: List[Completed] = []
        for k, g in enumerate(groups[:K]):
            for n, m in enumerate(g):
                if n >= N:
                    break
                done.append(Completed(prompt=prompts[m], image=imgs[k, n],
                                      group_id=k, nfe_share=nfe / len(prompts)))
        return done

    @property
    def cost_saving(self) -> float:
        if not self.stats["nfe_independent"]:
            return 0.0
        return 1.0 - self.stats["nfe"] / self.stats["nfe_independent"]
