"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attn image layers.  [hf:meta-llama/Llama-3.2-11B-Vision]

Backbone only per the brief: the ViT vision encoder is a STUB —
``input_specs()`` supplies precomputed patch embeddings
(n_image_tokens=1024, vision_dim=1280) fed through a learned projector.
Cross-attention every 5th layer: (attn x4, cross_attn) super-block x 8.
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, head_dim=128,
        mlp_kind="swiglu", rope_theta=5e5,
        pattern=("attn", "attn", "attn", "attn", "cross_attn"),
        n_image_tokens=1024, vision_dim=1280,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm",
        n_layers=5, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=64,
        mlp_kind="swiglu",
        pattern=("attn", "attn", "attn", "attn", "cross_attn"),
        n_image_tokens=16, vision_dim=64,
    )


register("llama-3.2-vision-11b", full, smoke)
