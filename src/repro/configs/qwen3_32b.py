"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-8B family scaled per assignment]
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        d_ff=25600, vocab=151936, head_dim=128,
        qk_norm=True, mlp_kind="swiglu", rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=32,
        qk_norm=True, mlp_kind="swiglu", rope_theta=1e6,
    )


register("qwen3-32b", full, smoke)
