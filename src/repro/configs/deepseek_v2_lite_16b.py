"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff_expert=1408
vocab=102400, MLA kv_lora=512, 2 shared + 64 routed top-6.  [arXiv:2405.04434]

The assignment line says both "MoE 64e" and "160 routed"; we follow the
published V2-Lite (64 routed, 2 shared, top-6) and note the discrepancy in
DESIGN.md §6.  First layer is dense with d_ff 10944.
"""
from repro.config import MLAConfig, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab=102400,
        attn_kind="mla", mlp_kind="swiglu",
        mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128),
        moe=MoEConfig(n_routed=64, top_k=6, d_ff_expert=1408,
                      n_shared=2, first_moe_layer=1, d_ff_dense=10944),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=512,
        attn_kind="mla", mlp_kind="swiglu",
        mla=MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32),
        moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=128,
                      n_shared=2, first_moe_layer=1, d_ff_dense=512),
    )


register("deepseek-v2-lite-16b", full, smoke)
