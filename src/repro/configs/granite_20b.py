"""granite-20b [dense] — 52L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152, llama-arch, code.  [arXiv:2405.04324]

d_ff = 4*d_model -> non-gated GELU MLP (see DESIGN.md §6).
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152,
        mlp_kind="gelu", rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1,
        d_ff=1024, vocab=512,
        mlp_kind="gelu",
    )


register("granite-20b", full, smoke)
