"""sage-dit — the paper's own model, TPU-adapted (DESIGN.md §2).

The paper fine-tunes Stable Diffusion v1.5 (conv UNet, 860M).  Our
TPU-native backbone is a latent DiT of comparable scale with cross-attention
text conditioning: 28L d_model=1152 16H, patch 2 over 64x64x4 latents (4096
-> 1024 tokens), text cond 77x768.  ``sage-dit-100m`` is the end-to-end
training example scale (~100M); the smoke variant runs in unit tests.
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="sage-dit", family="dit",
        n_layers=28, d_model=1152, n_heads=16, n_kv_heads=16,
        d_ff=4608, vocab=0,
        mlp_kind="gelu", qk_norm=True,
        latent_size=64, latent_channels=4, patch=2,
        cond_dim=768, cond_len=77,
    )


def dit_100m() -> ModelConfig:
    return ModelConfig(
        name="sage-dit-100m", family="dit",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=0,
        mlp_kind="gelu", qk_norm=True,
        latent_size=32, latent_channels=4, patch=2,
        cond_dim=256, cond_len=64,   # byte-level prompts need ~48 tokens
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="sage-dit-smoke", family="dit",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=0,
        mlp_kind="gelu", qk_norm=True,
        latent_size=8, latent_channels=4, patch=2,
        # byte-level prompts are ~40 chars; cond_len must cover the full
        # caption or grouped prompts collapse to identical conditioning
        cond_dim=64, cond_len=48,
    )


register("sage-dit", full, smoke)
register("sage-dit-100m", dit_100m, smoke)
