"""mamba2-780m [ssm] — 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060]
"""
from repro.config import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=48, n_kv_heads=48,  # SSD heads
        d_ff=0, vocab=50280, tie_embeddings=True,
        pattern=("ssm",),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                      chunk=128),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
        d_ff=0, vocab=512, tie_embeddings=True,
        pattern=("ssm",),
        ssm=SSMConfig(d_state=32, head_dim=64, expand=2, conv_kernel=4,
                      chunk=32),
    )


register("mamba2-780m", full, smoke)
