"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064, RoPE SwiGLU GQA.  [arXiv:2404.14219]
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064,
        mlp_kind="swiglu", rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=512,
        mlp_kind="swiglu",
    )


register("phi3-mini-3.8b", full, smoke)
