"""Architecture registry — one module per assigned architecture.

Importing this package registers every config with ``repro.config``.
"""
from repro.configs import (  # noqa: F401
    qwen1_5_32b,
    mamba2_780m,
    phi3_mini_3_8b,
    granite_20b,
    seamless_m4t_large_v2,
    llama_3_2_vision_11b,
    qwen3_32b,
    kimi_k2_1t_a32b,
    recurrentgemma_2b,
    deepseek_v2_lite_16b,
    sage_dit,
)

ASSIGNED = [
    "qwen1.5-32b",
    "mamba2-780m",
    "phi3-mini-3.8b",
    "granite-20b",
    "seamless-m4t-large-v2",
    "llama-3.2-vision-11b",
    "qwen3-32b",
    "kimi-k2-1t-a32b",
    "recurrentgemma-2b",
    "deepseek-v2-lite-16b",
]
