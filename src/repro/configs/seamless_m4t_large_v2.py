"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206, enc-dec multimodal.  [arXiv:2308.11596]

Transformer backbone only per the brief: the mel-spectrogram + conformer
conv frontend is a STUB — ``input_specs()`` supplies precomputed frame
embeddings (enc_input_dim=1024).  24 encoder + 24 decoder layers
(DESIGN.md §6).
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206,
        mlp_kind="gelu",
        enc_layers=24, enc_input_dim=1024,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="encdec",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=512,
        mlp_kind="gelu",
        enc_layers=2, enc_input_dim=256,
    )


register("seamless-m4t-large-v2", full, smoke)
