"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family scaled per assignment]
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab=152064,
        qkv_bias=True, mlp_kind="swiglu", rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=512,
        qkv_bias=True, mlp_kind="swiglu", rope_theta=1e6,
    )


register("qwen1.5-32b", full, smoke)
