"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attn 1:2.  [arXiv:2402.19427]

Layer pattern: (rglru, rglru, local_attn) super-blocks; 26 = 8*3 + 2, the
remainder is two recurrent layers (Griffin puts attention every third layer).
Local attention window 2048 per the paper.
"""
from repro.config import ModelConfig, RGLRUConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        d_ff=7680, vocab=256000, head_dim=256,
        mlp_kind="swiglu", window=2048, tie_embeddings=True,
        pattern=("rglru", "rglru", "local_attn"),
        remainder=("rglru", "rglru"),
        rglru=RGLRUConfig(lru_width=2560, conv_kernel=4, block_width=256),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=3, d_model=256, n_heads=2, n_kv_heads=1,
        d_ff=512, vocab=512, head_dim=128,
        mlp_kind="swiglu", window=64, tie_embeddings=True,
        pattern=("rglru", "rglru", "local_attn"),
        remainder=(),
        rglru=RGLRUConfig(lru_width=256, conv_kernel=4, block_width=64),
    )


register("recurrentgemma-2b", full, smoke)
