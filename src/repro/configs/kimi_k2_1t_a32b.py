"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8.  [arXiv:2501.kimi2]

Assignment specifies GQA kv=8 (the public K2 uses MLA; MLA is exercised by
deepseek-v2-lite here — see DESIGN.md §6).  First layer dense (d_ff 18432),
one shared expert, 384 routed top-8.
"""
from repro.config import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=18432, vocab=163840, head_dim=128,
        mlp_kind="swiglu", rope_theta=5e4,
        moe=MoEConfig(n_routed=384, top_k=8, d_ff_expert=2048,
                      n_shared=1, first_moe_layer=1, d_ff_dense=18432),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=64,
        mlp_kind="swiglu",
        moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=128,
                      n_shared=1, first_moe_layer=1, d_ff_dense=512),
    )


register("kimi-k2-1t-a32b", full, smoke)
