"""Mamba2 / SSD (state-space duality) mixer.  [arXiv:2405.21060]

Chunked SSD for train/prefill (quadratic intra-chunk + linear inter-chunk
recurrence) and an O(1)-state single-step recurrence for decode.  Single
B/C group (n_groups=1 in all assigned configs) — noted in DESIGN.md.

Cache: {"conv": (B, K-1, conv_dim), "state": (B, H, P, N)}.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, dot, rms_norm

Params = Dict[str, Any]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# depthwise causal conv1d
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B,S,C), w (K,C) depthwise causal -> (B,S,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],     # (K, 1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=w.shape[1])
    return out.astype(x.dtype)


def conv_step(window: jax.Array, w: jax.Array) -> jax.Array:
    """window (B,K,C) — the last K inputs (newest last) -> (B,C)."""
    return jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(window.dtype)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """x (..., Q) -> (..., Q, Q); out[i,j] = sum_{k=j+1..i} x_k (i>=j)."""
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    Q = x.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, NEG_INF)


def ssd_chunked(x: jax.Array, dA: jax.Array, B_: jax.Array, C_: jax.Array,
                chunk: int, init_state=None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan.  x (b,l,h,p) — already multiplied by dt;
    dA (b,l,h) = dt * A (negative); B_/C_ (b,l,n).
    Returns y (b,l,h,p) and final state (b,h,p,n).  fp32 internally.
    """
    b, l, h, p = x.shape
    n = B_.shape[-1]
    # pad the tail to a chunk multiple: zero inputs with dA=0 (decay=1)
    # leave y[:l] and the final state untouched.
    l0 = l
    if l % chunk:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        l = l + pad
    c, Q = l // chunk, chunk
    xf = x.astype(jnp.float32).reshape(b, c, Q, h, p)
    Bf = B_.astype(jnp.float32).reshape(b, c, Q, n)
    Cf = C_.astype(jnp.float32).reshape(b, c, Q, n)
    A = dA.astype(jnp.float32).reshape(b, c, Q, h).transpose(0, 3, 1, 2)  # b h c Q
    A_cum = jnp.cumsum(A, axis=-1)

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(A))                                   # (b,h,c,Q,Q)
    Y_diag = jnp.einsum("bzqn,bzsn,bhzqs,bzshp->bzqhp", Cf, Bf, L, xf)

    # per-chunk input states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)           # (b,h,c,Q)
    states = jnp.einsum("bzqn,bhzq,bzqhp->bzhpn", Bf, decay_states, xf)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(A_cum[..., -1])                     # (b,h,c)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        st, dec = inp                                         # (b,h,p,n), (b,h)
        s_new = s * dec[..., None, None] + st
        return s_new, s                                        # emit state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,c,h,p,n)

    state_decay_out = jnp.exp(A_cum)                          # (b,h,c,Q)
    Y_off = jnp.einsum("bzqn,bzhpn,bhzq->bzqhp", Cf, prev_states, state_decay_out)
    y = (Y_diag + Y_off).reshape(b, l, h, p)[:, :l0]
    return y.astype(x.dtype), final


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    """Separate z/x/BC/dt projections (instead of one packed in_proj) so the
    wide dims shard cleanly on the model axis (DESIGN.md §5)."""
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "z_proj": dense_init(k4, d, d_in, dtype),
        "x_proj": dense_init(k1, d, d_in, dtype),
        "bc_proj": dense_init(k5, d, 2 * s.d_state, dtype),
        "dt_proj": dense_init(k6, d, H, dtype),
        "conv_w": (jax.random.normal(k2, (s.conv_kernel, conv_dim), dtype)
                   / s.conv_kernel),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(k3, d_in, d, dtype),
    }


def _ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return s, d_in, H


def _ssm_split(p, cfg, u):
    """Projections.  u (B,S,D) -> z (B,S,d_in), xBC (B,S,conv_dim), dt (B,S,H)."""
    z = dot(u, p["z_proj"])
    xBC = jnp.concatenate([dot(u, p["x_proj"]), dot(u, p["bc_proj"])], -1)
    dt = dot(u, p["dt_proj"])
    return z, xBC, dt


def _ssm_post(p, cfg, y, z):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    return dot(y, p["out_proj"])


def ssm_full(p: Params, cfg: ModelConfig, u: jax.Array,
             init_state=None, return_cache: bool = False):
    """Train / prefill path.  u (B,S,D) -> (B,S,D) [, cache]."""
    s, d_in, H = _ssm_dims(cfg)
    B, S, _ = u.shape
    z, xBC, dt = _ssm_split(p, cfg, u)
    xBC_conv = jax.nn.silu(
        causal_conv1d(xBC, p["conv_w"]).astype(jnp.float32)
        + p["conv_b"].astype(jnp.float32)).astype(u.dtype)
    x, B_, C_ = jnp.split(xBC_conv, [d_in, d_in + s.d_state], axis=-1)
    x = x.reshape(B, S, H, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    y, final = ssd_chunked(x * dt[..., None].astype(x.dtype),
                           dt * A, B_, C_, s.chunk, init_state)
    y = y + x * p["D"].astype(x.dtype)[None, None, :, None]
    out = _ssm_post(p, cfg, y.reshape(B, S, d_in), z)
    if return_cache:
        K = s.conv_kernel
        conv_tail = xBC[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
            xBC, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"conv": conv_tail, "state": final}
    return out


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> Params:
    s, d_in, H = _ssm_dims(cfg)
    conv_dim = d_in + 2 * s.d_state
    return {"conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype),
            "state": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32)}


def ssm_decode(p: Params, cfg: ModelConfig, u: jax.Array, cache: Params,
               ) -> Tuple[jax.Array, Params]:
    """One-step recurrence.  u (B,1,D)."""
    s, d_in, H = _ssm_dims(cfg)
    B = u.shape[0]
    z, xBC, dt = _ssm_split(p, cfg, u)
    window = jnp.concatenate([cache["conv"], xBC], axis=1)            # (B,K,conv)
    xBC_c = jax.nn.silu(conv_step(window, p["conv_w"]).astype(jnp.float32)
                        + p["conv_b"].astype(jnp.float32)).astype(u.dtype)
    x, B_, C_ = jnp.split(xBC_c, [d_in, d_in + s.d_state], axis=-1)   # (B, .)
    x = x.reshape(B, H, s.head_dim)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt1 * A)                                             # (B,H)
    xf = x.astype(jnp.float32) * dt1[..., None]
    state = (cache["state"] * dA[..., None, None]
             + jnp.einsum("bhp,bn->bhpn", xf, B_.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", state, C_.astype(jnp.float32)).astype(u.dtype)
    y = y + x * p["D"].astype(x.dtype)[None, :, None]
    out = _ssm_post(p, cfg, y.reshape(B, 1, d_in), z)
    return out, {"conv": window[:, 1:], "state": state}
