"""Small convolutional VAE for the latent-diffusion substrate.

Encoder: 3 stride-2 conv stages (8x spatial reduction) -> (mean, logvar) of a
``latent_channels`` latent.  Decoder mirrors with resize+conv.  This is the
`E`/`D` of the paper's LDM formulation — small because the offline substrate
trains on synthetic images, but structurally complete (KL + recon training
in ``examples/train_vae.py`` path).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

_CH = (32, 64, 128)


def _conv_init(key, k, cin, cout):
    scale = 1.0 / jnp.sqrt(k * k * cin)
    return jax.random.normal(key, (k, k, cin, cout)) * scale


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def init_params(key, image_channels: int = 3, latent_channels: int = 4) -> Params:
    ks = jax.random.split(key, 8)
    enc = {}
    cin = image_channels
    for i, ch in enumerate(_CH):
        enc[f"w{i}"] = _conv_init(ks[i], 3, cin, ch)
        cin = ch
    enc["out"] = _conv_init(ks[3], 1, cin, 2 * latent_channels)
    dec = {"in": _conv_init(ks[4], 1, latent_channels, _CH[-1])}
    cin = _CH[-1]
    for i, ch in enumerate(reversed(_CH[:-1])):
        dec[f"w{i}"] = _conv_init(ks[5 + i], 3, cin, ch)
        cin = ch
    dec["out"] = _conv_init(ks[7], 3, cin, image_channels)
    return {"enc": enc, "dec": dec}


def encode(p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,H,W,3) in [-1,1] -> (mean, logvar), spatial /8."""
    h = x
    for i in range(len(_CH)):
        h = jax.nn.silu(_conv(h, p["enc"][f"w{i}"], stride=2))
    out = _conv(h, p["enc"]["out"])
    mean, logvar = jnp.split(out, 2, axis=-1)
    return mean, jnp.clip(logvar, -10.0, 10.0)


def sample(key, mean: jax.Array, logvar: jax.Array) -> jax.Array:
    return mean + jnp.exp(0.5 * logvar) * jax.random.normal(key, mean.shape)


def decode(p: Params, z: jax.Array) -> jax.Array:
    h = jax.nn.silu(_conv(z, p["dec"]["in"]))
    for i in range(len(_CH) - 1):
        B, H, W, C = h.shape
        h = jax.image.resize(h, (B, H * 2, W * 2, C), "nearest")
        h = jax.nn.silu(_conv(h, p["dec"][f"w{i}"]))
    B, H, W, C = h.shape
    h = jax.image.resize(h, (B, H * 2, W * 2, C), "nearest")
    return jnp.tanh(_conv(h, p["dec"]["out"]))


def vae_loss(p: Params, key, x: jax.Array, kl_weight: float = 1e-3):
    mean, logvar = encode(p, x)
    z = sample(key, mean, logvar)
    recon = decode(p, z)
    rec = jnp.mean((recon - x) ** 2)
    kl = 0.5 * jnp.mean(mean ** 2 + jnp.exp(logvar) - 1.0 - logvar)
    return rec + kl_weight * kl, {"rec": rec, "kl": kl}
