"""Model assembly: every assigned architecture family behind one API.

Layer stacks are ``lax.scan`` over stacked params (compile-time friendly for
52-64 layer models); non-uniform families scan *super-blocks* (VLM: 4 self +
1 cross; RecurrentGemma: rglru,rglru,local_attn) with remainders unrolled.

Public API
----------
init_params(cfg, key)                          -> params
forward_train(params, cfg, tokens, extras)     -> (logits, aux)
prefill(params, cfg, tokens, extras, max_len)  -> (last_logits, cache)
decode_step(params, cfg, cache, token, pos)    -> (logits, cache)
init_cache(cfg, batch, max_len, window=...)    -> cache (zeros; decode-only entry)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (MIX_ATTN, MIX_CROSS_ATTN, MIX_LOCAL_ATTN, MIX_RGLRU,
                          MIX_SSM, ModelConfig)
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import dense_init, dot, init_mlp, apply_mlp, rms_norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# plan: how layers are grouped into (prefix, scanned blocks, suffix)
# ---------------------------------------------------------------------------

def plan(cfg: ModelConfig) -> Tuple[Tuple[str, ...], Tuple[str, ...], int,
                                    Tuple[str, ...]]:
    kinds = cfg.layer_kinds()
    if cfg.family == "moe":
        f = cfg.moe.first_moe_layer
        return kinds[:f], (MIX_ATTN,), cfg.n_layers - f, ()
    if cfg.family == "encdec":
        return (), (MIX_CROSS_ATTN,), cfg.n_layers, ()
    if cfg.pattern:
        n_blocks = (cfg.n_layers - len(cfg.remainder)) // len(cfg.pattern)
        return (), tuple(cfg.pattern), n_blocks, tuple(cfg.remainder)
    return (), (MIX_ATTN,), cfg.n_layers, ()


def _mlp_kind(cfg: ModelConfig, in_scan: bool) -> str:
    """'moe' | 'dense' | 'none' for a layer position."""
    if cfg.d_ff == 0 and cfg.moe is None:
        return "none"
    if cfg.moe is not None and in_scan:
        return "moe"
    return "dense"


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str, mlpk: str,
               dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"ln1": jnp.zeros((d,), dtype)}
    if kind in (MIX_ATTN, MIX_LOCAL_ATTN, MIX_CROSS_ATTN):
        if cfg.attn_kind == "mla":
            p["mix"] = attn.init_mla(k1, cfg, dtype)
        else:
            p["mix"] = attn.init_gqa(k1, cfg, dtype=dtype)
        if kind == MIX_CROSS_ATTN:
            p["lnx"] = jnp.zeros((d,), dtype)
            p["xattn"] = attn.init_gqa(k4, cfg, cross=True, dtype=dtype)
    elif kind == MIX_SSM:
        p["mix"] = ssm_lib.init_ssm(k1, cfg, dtype)
    elif kind == MIX_RGLRU:
        p["mix"] = rglru_lib.init_rglru(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if mlpk == "dense":
        ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
              else cfg.d_ff)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = init_mlp(k2, d, ff, cfg.mlp_kind, dtype)
    elif mlpk == "moe":
        p["ln2"] = jnp.zeros((d,), dtype)
        p["moe"] = moe_lib.init_moe(k3, cfg, dtype)
    return p


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype, window: int = 0) -> Optional[Params]:
    if kind in (MIX_ATTN, MIX_CROSS_ATTN):
        L = window or max_len
        if cfg.attn_kind == "mla":
            c = attn.mla_cache_init(cfg, batch, L, dtype)
        else:
            c = attn.gqa_cache_init(cfg, batch, L, dtype)
        if kind == MIX_CROSS_ATTN:
            n_mem = (cfg.n_image_tokens if cfg.family == "vlm"
                     else _enc_len_default(cfg))
            c = {"self": c,
                 "cross": {"k": jnp.zeros((batch, n_mem, cfg.n_kv_heads, cfg.hd), dtype),
                           "v": jnp.zeros((batch, n_mem, cfg.n_kv_heads, cfg.hd), dtype)}}
        return c
    if kind == MIX_LOCAL_ATTN:
        return attn.gqa_cache_init(cfg, batch, min(cfg.window, max_len), dtype)
    if kind == MIX_SSM:
        return ssm_lib.ssm_cache_init(cfg, batch, dtype)
    if kind == MIX_RGLRU:
        return rglru_lib.rglru_cache_init(cfg, batch, dtype)
    raise ValueError(kind)


_ENC_LEN = 4096  # default encoder memory length for enc-dec decode caches


def _enc_len_default(cfg: ModelConfig) -> int:
    return _ENC_LEN


def apply_layer(p: Params, cfg: ModelConfig, kind: str, mlpk: str,
                x: jax.Array, *, mode: str, cache=None, pos=None,
                memory=None, window: int = 0, ring: bool = False,
                max_len: int = 0):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    new_cache = cache
    if kind in (MIX_ATTN, MIX_LOCAL_ATTN, MIX_CROSS_ATTN):
        w = cfg.window if kind == MIX_LOCAL_ATTN else window
        self_cache = cache["self"] if (kind == MIX_CROSS_ATTN and cache is not None) else cache
        if mode == "train":
            if cfg.attn_kind == "mla":
                a = attn.mla_full(p["mix"], cfg, h)
            else:
                a = attn.gqa_full(p["mix"], cfg, h, window=w)
            nsc = None
        elif mode == "prefill":
            if cfg.attn_kind == "mla":
                a, nsc = attn.mla_prefill(p["mix"], cfg, h, max_len=max_len)
            else:
                L = min(w, max_len) if w else max_len
                a, nsc = attn.gqa_prefill(p["mix"], cfg, h, max_len=L,
                                          window=w)
        else:  # decode
            if cfg.attn_kind == "mla":
                a, nsc = attn.mla_decode(p["mix"], cfg, h, self_cache, pos)
            else:
                a, nsc = attn.gqa_decode(p["mix"], cfg, h, self_cache, pos,
                                         ring=ring or kind == MIX_LOCAL_ATTN)
        x = x + a
        if kind == MIX_CROSS_ATTN:
            hx = rms_norm(x, p["lnx"], cfg.rms_eps)
            if mode == "train":
                x = x + attn.gqa_full(p["xattn"], cfg, hx, causal=False,
                                      memory=memory)
                new_cache = None
            elif mode == "prefill":
                x = x + attn.gqa_full(p["xattn"], cfg, hx, causal=False,
                                      memory=memory)
                xkv = attn.gqa_cross_cache(p["xattn"], cfg, memory)
                new_cache = {"self": nsc, "cross": xkv}
            else:
                xkv = cache["cross"]
                x = x + attn.gqa_cross_decode(p["xattn"], cfg, hx, xkv)
                new_cache = {"self": nsc, "cross": xkv}
        else:
            new_cache = nsc
    elif kind == MIX_SSM:
        if mode == "train":
            x = x + ssm_lib.ssm_full(p["mix"], cfg, h)
        elif mode == "prefill":
            a, new_cache = ssm_lib.ssm_full(p["mix"], cfg, h, return_cache=True)
            x = x + a
        else:
            a, new_cache = ssm_lib.ssm_decode(p["mix"], cfg, h, cache)
            x = x + a
    elif kind == MIX_RGLRU:
        if mode == "train":
            x = x + rglru_lib.rglru_full(p["mix"], cfg, h)
        elif mode == "prefill":
            a, new_cache = rglru_lib.rglru_full(p["mix"], cfg, h,
                                                return_cache=True)
            x = x + a
        else:
            a, new_cache = rglru_lib.rglru_decode(p["mix"], cfg, h, cache)
            x = x + a
    else:
        raise ValueError(kind)

    if mlpk == "dense":
        x = x + apply_mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.rms_eps),
                          cfg.mlp_kind)
    elif mlpk == "moe":
        y, aux = moe_lib.apply_moe(p["moe"], cfg,
                                   rms_norm(x, p["ln2"], cfg.rms_eps))
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    import numpy as np
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    prefix, block, n_blocks, suffix = plan(cfg)
    d = cfg.d_model
    p: Params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, d), dtype) * 0.02
                  if cfg.vocab else None),
        "ln_f": jnp.zeros((d,), dtype),
    }
    if cfg.vocab and not cfg.tie_embeddings:
        p["head"] = dense_init(keys[1], d, cfg.vocab, dtype)

    def init_block(k):
        bks = jax.random.split(k, len(block))
        return {f"l{j}": init_layer(bks[j], cfg, kind, _mlp_kind(cfg, True),
                                    dtype)
                for j, kind in enumerate(block)}

    p["prefix"] = [init_layer(jax.random.fold_in(keys[2], i), cfg, kind,
                              _mlp_kind(cfg, False), dtype)
                   for i, kind in enumerate(prefix)]
    p["blocks"] = jax.vmap(init_block)(jax.random.split(keys[3], n_blocks))
    p["suffix"] = [init_layer(jax.random.fold_in(keys[4], i), cfg, kind,
                              _mlp_kind(cfg, False), dtype)
                   for i, kind in enumerate(suffix)]

    if cfg.family == "vlm":
        p["proj"] = dense_init(keys[5], cfg.vision_dim, d, dtype)
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[6], cfg.enc_layers)

        def init_enc(k):
            return {"l0": init_layer(k, cfg, MIX_ATTN, "dense", dtype)}

        p["enc_in"] = dense_init(keys[7], cfg.enc_input_dim, d, dtype)
        p["enc_blocks"] = jax.vmap(init_enc)(enc_keys)
        p["enc_ln"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# encoder (enc-dec only): bidirectional attention stack
# ---------------------------------------------------------------------------

def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           remat: bool = False, unroll: bool = False) -> jax.Array:
    x = dot(frames.astype(jnp.dtype(cfg.dtype)), params["enc_in"])

    def body(x, bp):
        h = rms_norm(x, bp["l0"]["ln1"], cfg.rms_eps)
        x = x + attn.gqa_full(bp["l0"]["mix"], cfg, h, causal=False)
        x = x + apply_mlp(bp["l0"]["mlp"],
                          rms_norm(x, bp["l0"]["ln2"], cfg.rms_eps),
                          cfg.mlp_kind)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=unroll)
    return rms_norm(x, params["enc_ln"], cfg.rms_eps)


def _memory(params: Params, cfg: ModelConfig, extras: Optional[Dict],
            remat: bool = False, unroll: bool = False
            ) -> Optional[jax.Array]:
    if cfg.family == "vlm":
        img = extras["image_embeds"].astype(jnp.dtype(cfg.dtype))
        return dot(img, params["proj"])
    if cfg.family == "encdec":
        return encode(params, cfg, extras["frames"], remat, unroll)
    return None


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------

def forward_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  extras: Optional[Dict] = None, remat: bool = False,
                  unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S) -> (logits (B,S,V), aux)."""
    prefix, block, n_blocks, suffix = plan(cfg)
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    memory = _memory(params, cfg, extras, remat, unroll)

    aux0 = jnp.zeros((), jnp.float32)
    for lp, kind in zip(params["prefix"], prefix):
        x, _, a = apply_layer(lp, cfg, kind, _mlp_kind(cfg, False), x,
                              mode="train", memory=memory)
        aux0 = aux0 + a

    def body(carry, bp):
        x, aux = carry
        for j, kind in enumerate(block):
            x, _, a = apply_layer(bp[f"l{j}"], cfg, kind,
                                  _mlp_kind(cfg, True), x, mode="train",
                                  memory=memory)
            aux = aux + a
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux0), _ = jax.lax.scan(body, (x, aux0), params["blocks"],
                                unroll=unroll)

    for lp, kind in zip(params["suffix"], suffix):
        x, _, a = apply_layer(lp, cfg, kind, _mlp_kind(cfg, False), x,
                              mode="train", memory=memory)
        aux0 = aux0 + a

    x = rms_norm(x, params["ln_f"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = dot(x, head)
    return logits, aux0


# ---------------------------------------------------------------------------
# cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None, window: int = 0) -> Params:
    """Zero cache for pure decode dry-runs (no prefill)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    prefix, block, n_blocks, suffix = plan(cfg)

    def blk_cache():
        return {f"l{j}": init_layer_cache(cfg, kind, batch, max_len, dtype,
                                          window)
                for j, kind in enumerate(block)}

    one = blk_cache()
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_blocks,) + a.shape), one)
    return {
        "prefix": [init_layer_cache(cfg, kind, batch, max_len, dtype, window)
                   for kind in prefix],
        "blocks": stacked,
        "suffix": [init_layer_cache(cfg, kind, batch, max_len, dtype, window)
                   for kind in suffix],
    }


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            extras: Optional[Dict] = None, max_len: int = 0,
            window: int = 0, unroll: bool = False
            ) -> Tuple[jax.Array, Params]:
    """Run the prompt, build the cache; returns last-position logits."""
    prefix, block, n_blocks, suffix = plan(cfg)
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    max_len = max_len or S
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    memory = _memory(params, cfg, extras, unroll=unroll)

    caches: Params = {"prefix": [], "suffix": []}
    for lp, kind in zip(params["prefix"], prefix):
        x, c, _ = apply_layer(lp, cfg, kind, _mlp_kind(cfg, False), x,
                              mode="prefill", memory=memory, max_len=max_len,
                              window=window, ring=bool(window))
        caches["prefix"].append(c)

    def body(x, bp):
        cs = {}
        for j, kind in enumerate(block):
            x, c, _ = apply_layer(bp[f"l{j}"], cfg, kind,
                                  _mlp_kind(cfg, True), x, mode="prefill",
                                  memory=memory, max_len=max_len,
                                  window=window, ring=bool(window))
            cs[f"l{j}"] = c
        return x, cs

    x, blk_caches = jax.lax.scan(body, x, params["blocks"],
                                 unroll=unroll)
    caches["blocks"] = blk_caches

    for lp, kind in zip(params["suffix"], suffix):
        x, c, _ = apply_layer(lp, cfg, kind, _mlp_kind(cfg, False), x,
                              mode="prefill", memory=memory, max_len=max_len,
                              window=window, ring=bool(window))
        caches["suffix"].append(c)

    x = rms_norm(x[:, -1:], params["ln_f"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return dot(x, head), caches


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                token: jax.Array, pos: jax.Array, ring: bool = False,
                unroll: bool = False) -> Tuple[jax.Array, Params]:
    """token (B,1) int32; pos scalar int32 -> (logits (B,1,V), cache)."""
    prefix, block, n_blocks, suffix = plan(cfg)
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)

    new_cache: Params = {"prefix": [], "suffix": []}
    for lp, kind, c in zip(params["prefix"], prefix, cache["prefix"]):
        x, nc, _ = apply_layer(lp, cfg, kind, _mlp_kind(cfg, False), x,
                               mode="decode", cache=c, pos=pos, ring=ring)
        new_cache["prefix"].append(nc)

    def body(x, scanned):
        bp, bc = scanned
        ncs = {}
        for j, kind in enumerate(block):
            x, nc, _ = apply_layer(bp[f"l{j}"], cfg, kind,
                                   _mlp_kind(cfg, True), x, mode="decode",
                                   cache=bc[f"l{j}"], pos=pos, ring=ring)
            ncs[f"l{j}"] = nc
        return x, ncs

    x, blk_cache = jax.lax.scan(body, x,
                                (params["blocks"], cache["blocks"]),
                                unroll=unroll)
    new_cache["blocks"] = blk_cache

    for lp, kind, c in zip(params["suffix"], suffix, cache["suffix"]):
        x, nc, _ = apply_layer(lp, cfg, kind, _mlp_kind(cfg, False), x,
                               mode="decode", cache=c, pos=pos, ring=ring)
        new_cache["suffix"].append(nc)

    x = rms_norm(x, params["ln_f"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return dot(x, head), new_cache


# ---------------------------------------------------------------------------
# LM loss
# ---------------------------------------------------------------------------

def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = False, unroll: bool = False) -> jax.Array:
    logits, aux = forward_train(params, cfg, batch["tokens"],
                                extras={k: v for k, v in batch.items()
                                        if k not in ("tokens", "labels")},
                                remat=remat, unroll=unroll)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold) + aux
