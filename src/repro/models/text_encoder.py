"""Two-tower CLIP-style encoders.

* text tower: byte-level causal transformer; per-token features feed the
  DiT cross-attention (the `c` of Alg. 1/2) and the EOS-pooled, L2-normalised
  embedding drives semantic grouping (cosine similarity, paper §2.2) and the
  CLIP-proxy metric.
* image tower: small patch transformer for the CLIP-proxy metric.

``contrastive_loss`` trains both towers jointly on (image, prompt) pairs so
the proxy metric is meaningful offline (no pretrained CLIP available).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, replace, get_config
from repro.models import attention as attn
from repro.models.layers import apply_mlp, dense_init, dot, init_mlp, rms_norm

Params = Dict[str, Any]


def text_cfg(dim: int = 256, layers: int = 4, vocab: int = 258) -> ModelConfig:
    return ModelConfig(name="text-tower", family="dense", n_layers=layers,
                       d_model=dim, n_heads=4, n_kv_heads=4, d_ff=4 * dim,
                       vocab=vocab, mlp_kind="gelu")


def init_text(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 2)

    def blk(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.zeros((cfg.d_model,)),
                "attn": attn.init_gqa(k1, cfg),
                "ln2": jnp.zeros((cfg.d_model,)),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind)}

    return {"embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02,
            "blocks": jax.vmap(blk)(jax.random.split(ks[1], cfg.n_layers)),
            "ln_f": jnp.zeros((cfg.d_model,))}


def encode_text(p: Params, cfg: ModelConfig, tokens: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,L) int32 (byte+2; 257=EOS pad) -> (features (B,L,d), pooled (B,d))."""
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(x, bp):
        x = x + attn.gqa_full(bp["attn"], cfg,
                              rms_norm(x, bp["ln1"]))
        x = x + apply_mlp(bp["mlp"], rms_norm(x, bp["ln2"]), cfg.mlp_kind)
        return x, None

    x, _ = jax.lax.scan(body, x, p["blocks"])
    x = rms_norm(x, p["ln_f"])
    # masked mean pool (pad id 257): far more separable than last-token
    # pooling on short templated prompts (EXPERIMENTS.md notes)
    not_pad = (tokens != 257).astype(jnp.float32)[..., None]
    pooled = jnp.sum(x * not_pad, axis=1) / jnp.maximum(
        jnp.sum(not_pad, axis=1), 1.0)
    pooled = pooled / jnp.linalg.norm(pooled, axis=-1, keepdims=True)
    return x, pooled


def init_image(key, dim: int = 256, patch: int = 8, image: int = 64,
               layers: int = 4) -> Params:
    n = (image // patch) ** 2
    cfg = text_cfg(dim, layers)
    ks = jax.random.split(key, 4)
    tower = init_text(ks[0], cfg)
    return {"cfg_dim": jnp.zeros((0,)),  # marker
            "patch_in": dense_init(ks[1], patch * patch * 3, dim),
            "pos": jax.random.normal(ks[2], (n, dim)) * 0.02,
            "blocks": tower["blocks"], "ln_f": tower["ln_f"]}


def encode_image(p: Params, images: jax.Array, dim: int = 256,
                 patch: int = 8, layers: int = 4) -> jax.Array:
    """images (B,H,W,3) in [-1,1] -> (B,d) L2-normalised."""
    B, H, W, C = images.shape
    cfg = text_cfg(dim, layers)
    x = images.reshape(B, H // patch, patch, W // patch, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, -1, patch * patch * C)
    x = dot(x, p["patch_in"]) + p["pos"][None]

    def body(x, bp):
        x = x + attn.gqa_full(bp["attn"], cfg, rms_norm(x, bp["ln1"]),
                              causal=False)
        x = x + apply_mlp(bp["mlp"], rms_norm(x, bp["ln2"]), cfg.mlp_kind)
        return x, None

    x, _ = jax.lax.scan(body, x, p["blocks"])
    pooled = jnp.mean(rms_norm(x, p["ln_f"]), axis=1)
    return pooled / jnp.linalg.norm(pooled, axis=-1, keepdims=True)


def contrastive_loss(text_p: Params, img_p: Params, cfg: ModelConfig,
                     tokens: jax.Array, images: jax.Array,
                     temp: float = 0.07) -> jax.Array:
    _, te = encode_text(text_p, cfg, tokens)
    ie = encode_image(img_p, images, dim=cfg.d_model, layers=cfg.n_layers)
    logits = te @ ie.T / temp
    labels = jnp.arange(tokens.shape[0])
    li = -jnp.mean(jax.nn.log_softmax(logits, axis=1)[labels, labels])
    lt = -jnp.mean(jax.nn.log_softmax(logits, axis=0)[labels, labels])
    return 0.5 * (li + lt)


def tokenize(prompts, max_len: int = 64) -> jnp.ndarray:
    """Byte tokenizer: bytes + BOS(256)/EOS+pad(257)."""
    import numpy as np
    out = np.full((len(prompts), max_len), 257, np.int32)
    for i, s in enumerate(prompts):
        bs = list(s.encode("utf-8"))[: max_len - 2]
        out[i, 0] = 256
        out[i, 1:1 + len(bs)] = bs
    return jnp.asarray(out)
