"""Attention mixers: GQA (covers MHA/MQA, bias, qk_norm, sliding window,
cross-attention) and DeepSeek-style MLA with compressed-KV caching.

Cache layouts
-------------
GQA:  {"k": (B, L, Hkv, hd), "v": (B, L, Hkv, hd)}   L = max_len or window
MLA:  {"ckv": (B, L, kv_lora), "kr": (B, L, rope_hd)}

Sliding-window serving uses the same layout with L = window and ring-buffer
addressing (slot = pos % window); RoPE is applied *before* caching, so slot
order is irrelevant to the attention math.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import dispatch
from repro.models.layers import (apply_rope, attend, causal_mask,
                                 dense_init, dot, rms_norm)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, cross: bool = False,
             dtype=jnp.float32) -> Params:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    del cross  # cross-attn memory is already projected to d_model
    p: Params = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array,
         kv_src: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    hd = cfg.hd
    q = dot(x, p["wq"])
    k = dot(kv_src, p["wk"])
    v = dot(kv_src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    return q, k, v


def gqa_full(p: Params, cfg: ModelConfig, x: jax.Array, *,
             causal: bool = True, window: int = 0,
             memory: Optional[jax.Array] = None,
             pos0: int = 0) -> jax.Array:
    """Full-sequence attention (training / encoder / cross).

    Backend comes from ``cfg.attn_impl`` via the kernel dispatch layer:
    ``pallas`` runs the flash-attention kernel for both self-attention and
    cross-attention (padded cond keys masked via seq_k inside the kernel).
    """
    kv_src = memory if memory is not None else x
    q, k, v = _qkv(p, cfg, x, kv_src)
    if memory is None:  # self-attention gets RoPE
        pos = jnp.arange(x.shape[1]) + pos0
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = dispatch.attention(q, k, v, impl=cfg.attn_impl, causal=causal,
                             window=window, block=cfg.attn_block,
                             scale=1.0 / math.sqrt(cfg.hd),
                             interpret=cfg.kernel_interpret)
    return dot(out.reshape(*x.shape[:2], -1), p["wo"])


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype) -> Params:
    shp = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def gqa_prefill(p: Params, cfg: ModelConfig, x: jax.Array, *,
                max_len: int, window: int = 0) -> Tuple[jax.Array, Params]:
    """Causal self-attention over the prompt; returns output + filled cache.

    With ``window`` the cache holds the last ``window`` (ring layout —
    consistent with :func:`gqa_decode` since S % window slots line up when
    the prompt is written sequentially; here we write rows at i % window).
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, x)
    pos = jnp.arange(S)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    # backend from cfg.attn_impl like gqa_full — "pallas" runs the flash
    # kernel (incl. the sliding-window index-map variant) instead of the
    # old hard-coded chunked/naive branch
    out = dispatch.attention(q, k, v, impl=cfg.attn_impl, causal=True,
                             window=window, block=cfg.attn_block,
                             scale=1.0 / math.sqrt(cfg.hd),
                             interpret=cfg.kernel_interpret)
    cache = gqa_cache_init(cfg, B, max_len, k.dtype)
    if window and max_len == window and S >= window:
        # ring layout: keep the last `window` rows at slot = abs_pos % window
        slots = jnp.arange(S - window, S) % window
        cache = {"k": cache["k"].at[:, slots].set(k[:, -window:]),
                 "v": cache["v"].at[:, slots].set(v[:, -window:])}
    else:
        cache = {"k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                 "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)}
    return dot(out.reshape(B, S, -1), p["wo"]), cache


def gqa_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
               pos: jax.Array, *, ring: bool = False,
               memory_kv: Optional[Params] = None
               ) -> Tuple[jax.Array, Params]:
    """One-token decode. x (B,1,D); pos scalar int32 (current index)."""
    B = x.shape[0]
    q, k, v = _qkv(p, cfg, x, x)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)
    L = cache["k"].shape[1]
    slot = pos % L if ring else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
    valid = (jnp.arange(L) <= pos)[None, None, None, None, :]
    out = attend(q, ck, cv, valid, 1.0 / math.sqrt(cfg.hd))
    return dot(out.reshape(B, 1, -1), p["wo"]), {"k": ck, "v": cv}


def gqa_cross_cache(p: Params, cfg: ModelConfig, memory: jax.Array) -> Params:
    """Precompute cross-attention K/V from encoder/image memory."""
    B, S, _ = memory.shape
    k = dot(memory, p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = dot(memory, p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(k.dtype).reshape(1, 1, cfg.n_kv_heads, cfg.hd)
        v = v + p["bv"].astype(v.dtype).reshape(1, 1, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    return {"k": k, "v": v}


def gqa_cross_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                     kv: Params) -> jax.Array:
    """Cross-attention of one (or few) query tokens against cached memory KV."""
    B, S, _ = x.shape
    q = dot(x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
    out = attend(q, kv["k"], kv["v"], None, 1.0 / math.sqrt(cfg.hd))
    return dot(out.reshape(B, S, -1), p["wo"])


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": dense_init(k1, d, H * qd, dtype),
        "wdkv": dense_init(k2, d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wukv": dense_init(k3, m.kv_lora_rank,
                           H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(k4, H * m.v_head_dim, d, dtype),
    }


def _mla_q(p, cfg, x, pos):
    m = cfg.mla
    B, S, _ = x.shape
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = dot(x, p["wq"]).reshape(B, S, cfg.n_heads, qd)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_ckv(p, cfg, x, pos):
    m = cfg.mla
    dkv = dot(x, p["wdkv"])
    ckv, kr = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.rms_eps)
    kr = apply_rope(kr[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def _mla_attend(p, cfg, q, ckv, kr, mask):
    """q (B,Sq,H,nope+rope); ckv (B,Sk,r); kr (B,Sk,rope)."""
    m = cfg.mla
    B, Sk, _ = ckv.shape
    H = cfg.n_heads
    up = dot(ckv, p["wukv"]).reshape(B, Sk, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(up, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, Sk, H, m.qk_rope_head_dim))],
        axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out = attend(q, k, v, mask, scale)
    return dot(out.reshape(B, q.shape[1], -1), p["wo"])


def mla_full(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q = _mla_q(p, cfg, x, pos)
    ckv, kr = _mla_ckv(p, cfg, x, pos)
    return _mla_attend(p, cfg, q, ckv, kr, causal_mask(S, S))


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


def mla_prefill(p: Params, cfg: ModelConfig, x: jax.Array, *,
                max_len: int) -> Tuple[jax.Array, Params]:
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q = _mla_q(p, cfg, x, pos)
    ckv, kr = _mla_ckv(p, cfg, x, pos)
    out = _mla_attend(p, cfg, q, ckv, kr, causal_mask(S, S))
    cache = mla_cache_init(cfg, B, max_len, ckv.dtype)
    cache = {"ckv": jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, 0, 1),
             "kr": jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr, 0, 1)}
    return out, cache


def mla_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Params,
               pos: jax.Array) -> Tuple[jax.Array, Params]:
    B = x.shape[0]
    q = _mla_q(p, cfg, x, pos[None])
    ckv, kr = _mla_ckv(p, cfg, x, pos[None])
    c2 = {"ckv": jax.lax.dynamic_update_slice_in_dim(
              cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, 1),
          "kr": jax.lax.dynamic_update_slice_in_dim(
              cache["kr"], kr.astype(cache["kr"].dtype), pos, 1)}
    L = c2["ckv"].shape[1]
    mask = (jnp.arange(L) <= pos)[None, None, None, None, :]
    out = _mla_attend(p, cfg, q, c2["ckv"], c2["kr"], mask)
    return out, c2
