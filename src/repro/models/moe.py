"""Mixture-of-Experts MLP with top-k routing.

Dispatch is sort-based with static capacity (dropless up to the capacity
factor): token-choice pairs are sorted by expert id inside fixed-size token
*groups* (kept local so the sort never crosses the data axis), packed into an
(E, C, d) buffer, run through a batched expert matmul, and scattered back
with the router weights.  This keeps compiled FLOPs proportional to
*active* experts (E*C ~ tokens*top_k*capacity_factor), which is what the
roofline analysis needs — a dense all-expert einsum would overcount ~E/k x.

Shared experts (DeepSeek-style) are a fused always-on MLP.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, dot

Params = Dict[str, Any]


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(tokens * top_k * cf / n_experts) + 1
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.moe
    d, ff = cfg.d_model, m.d_ff_expert
    kr, ke, ks = jax.random.split(key, 3)
    ekeys = jax.random.split(ke, 3)
    p: Params = {
        "router": dense_init(kr, d, m.n_routed, dtype),
        "wi": jax.vmap(lambda k: dense_init(k, d, ff, dtype))(
            jax.random.split(ekeys[0], m.n_routed)),
        "wg": jax.vmap(lambda k: dense_init(k, d, ff, dtype))(
            jax.random.split(ekeys[1], m.n_routed)),
        "wo": jax.vmap(lambda k: dense_init(k, ff, d, dtype))(
            jax.random.split(ekeys[2], m.n_routed)),
    }
    if m.n_shared:
        sf = m.n_shared * ff
        s1, s2, s3 = jax.random.split(ks, 3)
        p["shared"] = {"wi": dense_init(s1, d, sf, dtype),
                       "wg": dense_init(s2, d, sf, dtype),
                       "wo": dense_init(s3, sf, d, dtype)}
    return p


def _route_group(x: jax.Array, idx: jax.Array, w: jax.Array,
                 n_experts: int, capacity: int):
    """Pack one token group.  x (T,d); idx/w (T,k) -> buffer (E*C, d) plus
    scatter metadata.  Runs under vmap over groups."""
    T, k = idx.shape
    flat_e = idx.reshape(T * k)
    flat_w = w.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    # rank of each entry within its expert
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(T * k) - first
    keep = rank < capacity
    slot = jnp.where(keep, se * capacity + jnp.minimum(rank, capacity - 1), 0)
    gathered = jnp.where(keep[:, None], x[stok], 0.0)
    buf = jnp.zeros((n_experts * capacity, x.shape[-1]), x.dtype)
    buf = buf.at[slot].add(gathered)   # slots unique among kept entries
    return buf, (slot, stok, sw, keep)


def _unroute_group(out_buf: jax.Array, meta, T: int) -> jax.Array:
    slot, stok, sw, keep = meta
    vals = out_buf[slot] * (sw * keep)[:, None].astype(out_buf.dtype)
    y = jnp.zeros((T, out_buf.shape[-1]), out_buf.dtype)
    return y.at[stok].add(vals)


def apply_moe(p: Params, cfg: ModelConfig, x: jax.Array,
              group_size: int = 0) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (out (B,S,D), aux load-balance loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    wk, idx = jax.lax.top_k(probs, m.top_k)
    wk = wk / jnp.sum(wk, axis=-1, keepdims=True)          # renormalise top-k

    # aux loss: mean prob per expert * mean assignment fraction (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, m.n_routed, dtype=jnp.float32), axis=1),
        axis=0)
    aux = m.router_aux_coef * m.n_routed * jnp.sum(me * ce)

    g = group_size or min(T, 4096)
    n_groups = -(-T // g)
    pad = n_groups * g - T
    if pad:
        xt_p = jnp.pad(xt, ((0, pad), (0, 0)))
        idx_p = jnp.pad(idx, ((0, pad), (0, 0)))
        wk_p = jnp.pad(wk, ((0, pad), (0, 0)))
    else:
        xt_p, idx_p, wk_p = xt, idx, wk
    xg = xt_p.reshape(n_groups, g, d)
    ig = idx_p.reshape(n_groups, g, m.top_k)
    wg_ = wk_p.reshape(n_groups, g, m.top_k).astype(x.dtype)

    C = _capacity(g, m.top_k, m.n_routed, m.capacity_factor)
    buf, meta = jax.vmap(
        lambda xx, ii, ww: _route_group(xx, ii, ww, m.n_routed, C))(xg, ig, wg_)
    ebuf = buf.reshape(n_groups, m.n_routed, C, d)

    # batched expert MLP: (G,E,C,d) x (E,d,f)
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", ebuf, p["wg"].astype(x.dtype)))
         * jnp.einsum("gecd,edf->gecf", ebuf, p["wi"].astype(x.dtype)))
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    out_buf = out_buf.reshape(n_groups, m.n_routed * C, d)

    y = jax.vmap(lambda ob, mt: _unroute_group(ob, mt, g))(out_buf, meta)
    y = y.reshape(n_groups * g, d)[:T]

    if m.n_shared:
        sp = p["shared"]
        y = y + dot(jax.nn.silu(dot(xt, sp["wg"])) * dot(xt, sp["wi"]), sp["wo"])
    return y.reshape(B, S, d), aux


def apply_moe_dense_ref(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Oracle: compute every expert densely and mix with router weights.
    Matches apply_moe exactly when nothing is dropped.  Test-only."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    wk, idx = jax.lax.top_k(probs, m.top_k)
    wk = wk / jnp.sum(wk, axis=-1, keepdims=True)
    wfull = jnp.zeros_like(probs)
    wfull = jax.vmap(lambda w_, i_, row: row.at[i_].set(w_))(wk, idx, wfull)
    h = (jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["wg"].astype(x.dtype)))
         * jnp.einsum("td,edf->tef", xt, p["wi"].astype(x.dtype)))
    ey = jnp.einsum("tef,efd->ted", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("ted,te->td", ey, wfull.astype(x.dtype))
    if m.n_shared:
        sp = p["shared"]
        y = y + dot(jax.nn.silu(dot(xt, sp["wg"])) * dot(xt, sp["wi"]), sp["wo"])
    return y.reshape(B, S, d)
