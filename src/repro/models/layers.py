"""Shared neural-net building blocks (pure-functional, dict params).

Conventions
-----------
* params are nested dicts of jnp arrays (param_dtype, default fp32);
* activations run in ``cfg.dtype`` (default bf16) — weights are cast at the
  matmul site via :func:`dot`;
* shapes: x (B, S, D); attention heads last-but-one: q (B, S, H, hd).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------

def dot(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w with the weight cast to the activation dtype."""
    return x @ w.astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, kind: str, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wi": dense_init(ks[0], d, ff, dtype),
                "wg": dense_init(ks[1], d, ff, dtype),
                "wo": dense_init(ks[2], ff, d, dtype)}
    return {"wi": dense_init(ks[0], d, ff, dtype),
            "wo": dense_init(ks[2], ff, d, dtype)}


def apply_mlp(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return dot(jax.nn.silu(dot(x, p["wg"])) * dot(x, p["wi"]), p["wo"])
    return dot(jax.nn.gelu(dot(x, p["wi"])), p["wo"])


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

def attend(q: jax.Array, k: jax.Array, v: jax.Array,
           mask: Optional[jax.Array], scale: float) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd) with H % Hkv == 0 -> (B,Sq,H,hd).

    Scores accumulate in fp32; GQA via reshape (no kv repeat materialised
    beyond the einsum broadcast).
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    q = q.reshape(B, Sq, Hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])  # v head dim may differ (MLA)


def causal_mask(sq: int, sk: int, q_offset: int = 0,
                window: int = 0) -> jax.Array:
    """(1,1,1,sq,sk) boolean mask; window=0 means full causal."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window:
        m &= ki > qi - window
    return m[None, None, None]


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: int, scale: float,
                   block: int = 1024) -> jax.Array:
    """Online-softmax attention with the key axis scanned in blocks — the
    jnp twin of kernels/flash_attention.  Never materialises the (Sq, Sk)
    score matrix: peak attention memory drops from O(Sq*Sk) to
    O(Sq*block), the memory-term lever for 32k prefill (EXPERIMENTS §Perf).
    Same signature semantics as :func:`attend`."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    pad = (-Sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (Sk + pad) // block
    qr = q.reshape(B, Sq, Hkv, g, hd)
    kb = k.reshape(B, nb, block, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, Hkv, hd).transpose(1, 0, 2, 3, 4)
    qi = jnp.arange(Sq)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, bi = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kc,
                       preferred_element_type=jnp.float32) * scale
        ki = bi * block + jnp.arange(block)
        valid = (ki[None, :] < Sk)
        if causal:
            valid = valid & (ki[None, :] <= qi[:, None])
        if window:
            valid = valid & (ki[None, :] > qi[:, None] - window)
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, g, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
