"""Latent DiT denoiser — the TPU-native adaptation of the paper's SD-v1.5
UNet backbone (DESIGN.md §2): patchified latent transformer, adaLN-zero
timestep conditioning, cross-attention text conditioning (PixArt-style).

eps = dit.forward(params, cfg, z_t, t, cond)   # epsilon-prediction
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models.layers import apply_mlp, dense_init, dot, init_mlp

Params = Dict[str, Any]

_TDIM = 256


def timestep_embedding(t: jax.Array, dim: int = _TDIM) -> jax.Array:
    """Sinusoidal embedding; t (B,) float or int -> (B, dim) fp32."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def _ln(x: jax.Array) -> jax.Array:
    """Parameter-free LayerNorm (affine comes from adaLN modulation)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


def _mod(x: jax.Array, shift: jax.Array, scale: jax.Array) -> jax.Array:
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def n_tokens(cfg: ModelConfig) -> int:
    return (cfg.latent_size // cfg.patch) ** 2


def init_params(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    p_in = cfg.patch * cfg.patch * cfg.latent_channels
    ks = jax.random.split(key, 10)

    def init_block(k):
        kb = jax.random.split(k, 4)
        return {
            "adaln": jnp.zeros((d, 6 * d), jnp.float32),
            "adaln_b": jnp.zeros((6 * d,), jnp.float32),
            "attn": attn.init_gqa(kb[0], cfg),
            "lnx": jnp.zeros((d,), jnp.float32),
            "xattn": attn.init_gqa(kb[1], cfg, cross=True),
            "mlp": init_mlp(kb[2], d, cfg.d_ff, cfg.mlp_kind),
        }

    return {
        "patch_in": dense_init(ks[0], p_in, d),
        "pos": jax.random.normal(ks[1], (n_tokens(cfg), d)) * 0.02,
        "t_w1": dense_init(ks[2], _TDIM, d),
        "t_w2": dense_init(ks[3], d, d),
        "cond_proj": dense_init(ks[4], cfg.cond_dim, d),
        "blocks": jax.vmap(init_block)(jax.random.split(ks[5], cfg.n_layers)),
        "final_adaln": jnp.zeros((d, 2 * d), jnp.float32),
        "final_adaln_b": jnp.zeros((2 * d,), jnp.float32),
        # small (not zero) init: a zero output matrix would also zero every
        # upstream gradient, which deadlocks LoRA fine-tuning (base frozen).
        "out": dense_init(ks[6], d, p_in) * 0.02,
    }


def patchify(cfg: ModelConfig, z: jax.Array) -> jax.Array:
    B, H, W, C = z.shape
    p = cfg.patch
    z = z.reshape(B, H // p, p, W // p, p, C).transpose(0, 1, 3, 2, 4, 5)
    return z.reshape(B, (H // p) * (W // p), p * p * C)


def unpatchify(cfg: ModelConfig, x: jax.Array, hw=None) -> jax.Array:
    B, n, _ = x.shape
    p, C = cfg.patch, cfg.latent_channels
    hp, wp = (int(math.isqrt(n)),) * 2 if hw is None else hw
    x = x.reshape(B, hp, wp, p, p, C).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, hp * p, wp * p, C)


def pos_embed(pos: jax.Array, cfg: ModelConfig, hp: int, wp: int
              ) -> jax.Array:
    """Positional table for an (hp, wp) patch grid.  The table is trained
    at the full square grid ``latent_size // patch``; smaller latents
    (multi-resolution / aspect-bucket serving) take the top-left window of
    the 2-D table, SDXL-crop style — the full-size path returns the table
    untouched, so square full-resolution latents are bit-for-bit the
    pre-hetero graph."""
    hw = cfg.latent_size // cfg.patch
    if (hp, wp) == (hw, hw):
        return pos
    if hp > hw or wp > hw:
        raise ValueError(f"patch grid ({hp},{wp}) exceeds pos table {hw}")
    return pos.reshape(hw, hw, -1)[:hp, :wp].reshape(hp * wp, -1)


def forward(params: Params, cfg: ModelConfig, z: jax.Array, t: jax.Array,
            cond: jax.Array, remat: bool = False) -> jax.Array:
    """z (B,H,W,C) latents at time t; t (B,); cond (B,Lc,cond_dim) -> eps.

    H and W need not equal ``cfg.latent_size`` (nor each other): any
    patch-divisible latent up to the trained grid runs through the same
    weights with a windowed positional table (:func:`pos_embed`)."""
    dtype = jnp.dtype(cfg.dtype)
    hp, wp = z.shape[1] // cfg.patch, z.shape[2] // cfg.patch
    x = dot(patchify(cfg, z).astype(dtype), params["patch_in"])
    x = x + pos_embed(params["pos"], cfg, hp, wp).astype(dtype)[None]
    temb = timestep_embedding(t)
    temb = dot(jax.nn.silu(dot(temb, params["t_w1"])), params["t_w2"])  # (B,d)
    c = dot(cond.astype(dtype), params["cond_proj"])                    # (B,Lc,d)
    tmod = jax.nn.silu(temb)

    def body(x, bp):
        mod = (tmod @ bp["adaln"].astype(tmod.dtype)
               + bp["adaln_b"].astype(tmod.dtype))
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        h = _mod(_ln(x), sh1.astype(dtype), sc1.astype(dtype))
        x = x + g1[:, None, :].astype(dtype) * attn.gqa_full(
            bp["attn"], cfg, h, causal=False)
        hx = _ln(x) * (1.0 + bp["lnx"].astype(dtype))
        x = x + attn.gqa_full(bp["xattn"], cfg, hx, causal=False, memory=c)
        h = _mod(_ln(x), sh2.astype(dtype), sc2.astype(dtype))
        x = x + g2[:, None, :].astype(dtype) * apply_mlp(bp["mlp"], h,
                                                         cfg.mlp_kind)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["blocks"])

    fmod = (tmod @ params["final_adaln"].astype(tmod.dtype)
            + params["final_adaln_b"].astype(tmod.dtype))
    shf, scf = jnp.split(fmod, 2, axis=-1)
    x = _mod(_ln(x), shf.astype(dtype), scf.astype(dtype))
    out = dot(x, params["out"])
    return unpatchify(cfg, out, (hp, wp)).astype(jnp.float32)
