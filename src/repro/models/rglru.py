"""RG-LRU recurrent block (Griffin / RecurrentGemma).  [arXiv:2402.19427]

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
r_t / i_t: sigmoid gates (dense here; block-diagonal in the paper — noted).

Train/prefill via jax.lax.associative_scan; decode is a single-step update.
Cache: {"conv": (B, K-1, W), "state": (B, W)}.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, dot
from repro.models.ssm import causal_conv1d, conv_step

Params = Dict[str, Any]

_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    K = cfg.rglru.conv_kernel
    ks = jax.random.split(key, 6)
    # init Lambda so a ~ U(0.9, 0.999)^c-ish (Griffin appendix)
    lam = jax.random.uniform(ks[4], (w,), jnp.float32, 0.38, 0.8)
    return {
        "wx": dense_init(ks[0], d, w, dtype),
        "wg": dense_init(ks[1], d, w, dtype),
        "conv_w": jax.random.normal(ks[2], (K, w), dtype) / K,
        "conv_b": jnp.zeros((w,), dtype),
        "wa": dense_init(ks[3], w, w, dtype),
        "ba": jnp.zeros((w,), dtype),
        "wi": dense_init(ks[5], w, w, dtype),
        "bi": jnp.zeros((w,), dtype),
        "lam": lam,
        "out": dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _gates(p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """log_a (fp32) and gated input sqrt(1-a^2)*i*x."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(dot(x, p["wa"]).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(dot(x, p["wi"]).astype(jnp.float32) + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * i * xf


def rglru_full(p: Params, cfg: ModelConfig, u: jax.Array,
               init_state=None, return_cache: bool = False):
    """u (B,S,D) -> (B,S,D) [, cache]."""
    B, S, _ = u.shape
    K = cfg.rglru.conv_kernel
    gate = jax.nn.gelu(dot(u, p["wg"]).astype(jnp.float32))
    xw = dot(u, p["wx"])
    x = causal_conv1d(xw, p["conv_w"]) + p["conv_b"].astype(xw.dtype)
    log_a, b = _gates(p, x)
    a = jnp.exp(log_a)
    if init_state is not None:
        # fold carried state into the first step: h_0' contribution
        b = b.at[:, 0].add(a[:, 0] * init_state.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * gate).astype(u.dtype)
    out = dot(y, p["out"])
    if return_cache:
        tail = xw[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
            xw, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"conv": tail, "state": h[:, -1]}
    return out


def rglru_cache_init(cfg: ModelConfig, batch: int, dtype) -> Params:
    w = cfg.rglru.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.rglru.conv_kernel - 1, w), dtype),
            "state": jnp.zeros((batch, w), jnp.float32)}


def rglru_decode(p: Params, cfg: ModelConfig, u: jax.Array, cache: Params
                 ) -> Tuple[jax.Array, Params]:
    """u (B,1,D)."""
    gate = jax.nn.gelu(dot(u, p["wg"]).astype(jnp.float32))[:, 0]
    xw = dot(u, p["wx"])                                    # (B,1,W)
    window = jnp.concatenate([cache["conv"], xw], axis=1)
    x = conv_step(window, p["conv_w"]) + p["conv_b"].astype(xw.dtype)
    log_a, b = _gates(p, x[:, None, :])
    h = jnp.exp(log_a[:, 0]) * cache["state"] + b[:, 0]
    y = (h * gate).astype(u.dtype)[:, None, :]
    return dot(y, p["out"]), {"conv": window[:, 1:], "state": h}
