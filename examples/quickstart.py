"""Quickstart: the SAGE pipeline end to end in ~a minute on CPU.

1. build a semantically grouped prompt set (procedural corpus),
2. group prompts by text-embedding similarity (paper Alg. 1 line 2),
3. run shared diffusion sampling (shared phase -> branch phase),
4. report the NFE cost saving vs independent sampling.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SageConfig, get_config
from repro.core import grouping
from repro.core.schedule import make_schedule
from repro.core.shared_sampling import independent_sample, shared_sample
from repro.data.synthetic import ShapesDataset
from repro.models import dit
from repro.models import text_encoder as te


def main():
    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=12, share_ratio=0.33, guidance_scale=4.0,
                      tau_min=0.35)
    sched = make_schedule(1000)

    print("== SAGE quickstart ==")
    ds = ShapesDataset(res=16)
    _, prompts = ds.batch(0, 12)
    for p in prompts[:4]:
        print("  prompt:", p)

    # text tower (untrained here; examples/train_sage.py trains it)
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    tp = te.init_text(jax.random.PRNGKey(0), tc)
    toks = te.tokenize(prompts, max_len=cfg.cond_len)
    cond, pooled = te.encode_text(tp, tc, toks)

    sim = grouping.similarity_matrix(np.asarray(pooled))
    groups = grouping.greedy_clique_groups(sim, sage.tau_min, group_max=4)
    print(f"grouped {len(prompts)} prompts into {len(groups)} groups:",
          [len(g) for g in groups])
    idx, mask = grouping.pad_groups(groups, 4)

    params = dit.init_params(cfg, jax.random.PRNGKey(1))
    eps_fn = lambda z, t, c: dit.forward(params, cfg, z, t, c)
    null = jnp.zeros((cfg.cond_len, cfg.cond_dim))
    H = cfg.latent_size
    cond_packed = jnp.asarray(cond)[idx.reshape(-1)].reshape(
        idx.shape + cond.shape[1:])

    out = shared_sample(eps_fn, sched, sage, jax.random.PRNGKey(2),
                        cond_packed, jnp.asarray(mask), null,
                        (H, H, cfg.latent_channels))
    indep = independent_sample(eps_fn, sched, sage, jax.random.PRNGKey(2),
                               jnp.asarray(cond), null,
                               (H, H, cfg.latent_channels))
    print(f"shared sampling   NFE = {int(out['nfe'])}")
    print(f"independent       NFE = {int(indep['nfe'])}")
    print(f"cost saving       = {1 - float(out['nfe'])/float(indep['nfe']):.1%}")
    print("latents:", out["latents"].shape, "finite:",
          bool(jnp.all(jnp.isfinite(out["latents"]))))


if __name__ == "__main__":
    main()
