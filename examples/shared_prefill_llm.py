"""SAGE's insight on an assigned LLM architecture: semantic shared-prefix
prefill.  Groups requests by prompt-embedding similarity, prefills each
group's common trunk once, forks the KV cache, and decodes per member —
the AR analogue of the paper's shared phase (DESIGN.md §4).

    PYTHONPATH=src python examples/shared_prefill_llm.py --arch phi3-mini-3.8b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import transformer as tfm
from repro.serving.shared_prefill import (common_prefix_len, group_requests,
                                          shared_prefix_prefill)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--prefix", type=int, default=48)
    ap.add_argument("--tail", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    S = args.prefix + args.tail

    total_saving, t0 = [], time.time()
    for g in range(args.groups):
        shared = rng.randint(0, cfg.vocab, (1, args.prefix))
        tokens = np.concatenate(
            [shared.repeat(args.members, 0),
             rng.randint(0, cfg.vocab, (args.members, args.tail))], axis=1)

        def prefill_fn(t, max_len):
            return tfm.prefill(params, cfg, jnp.asarray(t), max_len=max_len)

        def decode_fn(cache, tok, pos):
            return tfm.decode_step(params, cfg, cache, jnp.asarray(tok), pos)

        logits, caches, pos, stats = shared_prefix_prefill(
            prefill_fn, decode_fn, tokens, max_len=S + 32)
        total_saving.append(stats["saving"])
        print(f"group {g}: prefix={stats['prefix_len']} "
              f"steps={stats['token_steps']} vs naive "
              f"{stats['token_steps_naive']} -> saving {stats['saving']:.1%}")

    print(f"\narch={args.arch} mean prefill-compute saving "
          f"{np.mean(total_saving):.1%} across {args.groups} groups "
          f"({time.time()-t0:.1f}s, smoke-size weights)")


if __name__ == "__main__":
    main()
