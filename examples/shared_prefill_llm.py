"""SAGE's insight on an assigned LLM architecture: semantic shared-prefix
prefill.  Groups requests by prompt-embedding similarity, prefills each
group's common trunk once, forks the KV cache at the branch point, and
decodes per member — the AR analogue of the paper's shared phase
(DESIGN.md §4).

With ``--trunk-cache`` the prefill trunk additionally rides the *unified*
semantic cache (``payload="ar_prefix"`` in the same
:class:`~repro.serving.trunk_cache.TrunkCache` the diffusion scheduler
uses): groups drawn from a small prefix pool hit the cached
(logits, kv-cache) pair and skip the prefill entirely — cross-*batch*
reuse stacked on the within-group sharing.

    PYTHONPATH=src python examples/shared_prefill_llm.py --arch phi3-mini-3.8b
    PYTHONPATH=src python examples/shared_prefill_llm.py --trunk-cache \
        --groups 6 --prefix-pool 2 --cache-index lsh
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import transformer as tfm
from repro.serving.shared_prefill import (cached_prefix_prefill,
                                          common_prefix_len, group_requests,
                                          shared_prefix_prefill)
from repro.serving.trunk_cache import TrunkCache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--prefix", type=int, default=48)
    ap.add_argument("--tail", type=int, default=16)
    ap.add_argument("--trunk-cache", action="store_true",
                    help="serve prefill trunks from the unified semantic "
                         "cache (payload='ar_prefix')")
    ap.add_argument("--cache-index", choices=["scan", "lsh"],
                    default="scan",
                    help="candidate generation for the cache's "
                         "similarity search")
    ap.add_argument("--prefix-pool", type=int, default=2,
                    help="with --trunk-cache: number of distinct shared "
                         "prefixes groups draw from (repeats -> hits)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    S = args.prefix + args.tail

    cache = None
    if args.trunk_cache:
        cache = TrunkCache(tau_trunk=0.95, index=args.cache_index)
    # with a cache, groups draw their trunk from a small pool so later
    # groups exercise the cross-batch hit path
    pool = [rng.randint(0, cfg.vocab, (1, args.prefix))
            for _ in range(max(1, args.prefix_pool))]

    total_saving, t0 = [], time.time()
    for g in range(args.groups):
        shared = (pool[g % len(pool)] if cache is not None
                  else rng.randint(0, cfg.vocab, (1, args.prefix)))
        tokens = np.concatenate(
            [shared.repeat(args.members, 0),
             rng.randint(0, cfg.vocab, (args.members, args.tail))], axis=1)

        def prefill_fn(t, max_len):
            return tfm.prefill(params, cfg, jnp.asarray(t), max_len=max_len)

        def decode_fn(cache_, tok, pos):
            return tfm.decode_step(params, cfg, cache_, jnp.asarray(tok),
                                   pos)

        if cache is not None:
            # token-derived pseudo-embedding: enough to route the lookup
            # (real deployments use the prompt tower's pooled embedding)
            emb = np.asarray(tokens, np.float32)
            logits, caches, pos, stats = cached_prefix_prefill(
                prefill_fn, decode_fn, tokens, max_len=S + 32,
                cache=cache, embeds=emb)
            tag = " [cache hit]" if stats["trunk_cache_hit"] else ""
        else:
            logits, caches, pos, stats = shared_prefix_prefill(
                prefill_fn, decode_fn, tokens, max_len=S + 32)
            tag = ""
        total_saving.append(stats["saving"])
        print(f"group {g}: prefix={stats['prefix_len']} "
              f"steps={stats['token_steps']} vs naive "
              f"{stats['token_steps_naive']} -> saving "
              f"{stats['saving']:.1%}{tag}")

    print(f"\narch={args.arch} mean prefill-compute saving "
          f"{np.mean(total_saving):.1%} across {args.groups} groups "
          f"({time.time()-t0:.1f}s, smoke-size weights)")
    if cache is not None:
        st = cache.stats
        print(f"unified trunk cache [{cache.index.name}]: "
              f"{st['hits']} hits / {st['misses']} misses, "
              f"{len(cache)} entries, {cache.bytes} B "
              f"(ar_prefix payloads share the diffusion cache's "
              f"budget/admission/index)")


if __name__ == "__main__":
    main()
