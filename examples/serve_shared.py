"""Serve batched text-to-image requests through the SAGE engine: semantic
grouping + shared sampling + adaptive branch point + (optionally) the
beyond-paper shared-uncond CFG.

    PYTHONPATH=src python examples/serve_shared.py --requests 24 --adaptive

Streaming mode drives the continuous-batching scheduler instead of the
synchronous engine: requests arrive over virtual time as a Poisson
process, join open groups incrementally, advance in S-step segments per
tick, and (with --trunk-cache) reuse completed shared phases across
batches via the semantic trunk cache:

    PYTHONPATH=src python examples/serve_shared.py --requests 24 \\
        --streaming --arrival-rate 2.0 --trunk-cache --themes 4

Overload / chaos drills (streaming mode): ``--qos-mix`` tags a fraction
of arrivals as deadline-carrying interactive traffic (the rest is batch),
``--overload shed|degrade`` arms saturation admission past
``--shed-horizon`` ticks of estimated backlog, ``--max-groups-per-tick``
caps launch slots (the contended resource), and ``--fault-plan``
injects seeded faults (``launch=P,miss=P,corrupt=P,stall=P,seed=N``):

    PYTHONPATH=src python examples/serve_shared.py --requests 48 \\
        --streaming --arrival-rate 4.0 --themes 3 --qos-mix 0.25 \\
        --overload shed --max-groups-per-tick 2 \\
        --fault-plan launch=0.1,stall=0.05,seed=7

Telemetry (streaming mode): ``--trace out.json`` records the full
request/group/exec lifecycle as Chrome trace-event JSON (load in
Perfetto or chrome://tracing — deterministic under the virtual clock),
``--metrics out.prom`` writes the Prometheus exposition of every
counter/gauge/histogram plus the live kernel-dispatch fallback matrix,
and ``--report`` prints the joined SLO + capacity (dryrun cost model) +
dispatch report:

    PYTHONPATH=src python examples/serve_shared.py --requests 48 \\
        --streaming --trunk-cache --themes 4 \\
        --trace trace.json --metrics metrics.prom --report
"""
import argparse
import time

import jax
import numpy as np

from repro.config import SageConfig, get_config
from repro.data.synthetic import ShapesDataset
from repro.kernels.dispatch import DISPATCH_LOG
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving import reports
from repro.serving.engine import SageServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.policies import (PadAwarePolicy, SaturationAdmission,
                                    make_cache_admission)
from repro.serving.telemetry import MetricsRegistry, Tracer
from repro.serving.trunk_cache import TrunkCache


def build_engine(args):
    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=args.steps, share_ratio=0.3,
                      guidance_scale=4.0, tau_min=0.3,
                      adaptive_branch=args.adaptive,
                      shared_uncond_cfg=args.shared_uncond,
                      sampler=args.sampler)
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    return SageServingEngine(
        cfg, sage,
        dit_params=dit.init_params(cfg, jax.random.PRNGKey(0)),
        text_params=te.init_text(jax.random.PRNGKey(1), tc),
        text_cfg=tc, group_size=4,
        attn_impl=args.backend,
        step_impl="fused" if args.fused_step else None)


def run_sync(engine, prompts):
    engine.submit(prompts)
    t0 = time.time()
    done = []
    while engine.queue:
        done.extend(engine.step(max_batch=16))
    dt = time.time() - t0

    groups = {}
    for c in done:
        groups.setdefault(c.group_id, []).append(c.prompt)
    print(f"served {len(done)} requests in {dt:.1f}s "
          f"({len(groups)} groups)")
    for gid, ps in sorted(groups.items())[:5]:
        print(f"  group {gid}: {ps}")
    print(f"NFE total          = {engine.stats['nfe']:.0f}")
    print(f"NFE if independent = {engine.stats['nfe_independent']:.0f}")
    print(f"cost saving        = {engine.cost_saving:.1%}")


def run_streaming(engine, prompts, args):
    """Poisson arrival simulation over virtual time (1 tick = 1 time unit;
    the scheduler treats `now` as an opaque monotone clock)."""
    rng = np.random.RandomState(args.seed)
    gaps = rng.exponential(1.0 / max(args.arrival_rate, 1e-6), len(prompts))
    arrival_t = np.cumsum(gaps)

    cache = None
    if args.trunk_cache:
        kw = ({"threshold": args.popularity_threshold}
              if args.cache_admission == "popularity" else {})
        cache = TrunkCache(
            tau_trunk=args.tau_trunk,
            admission=make_cache_admission(args.cache_admission, **kw),
            index=args.cache_index,
            max_bytes=args.hbm_budget, host_bytes=args.host_budget)
    policy = (PadAwarePolicy(hold_ticks=args.hold_ticks)
              if args.policy == "pad_aware" else args.policy)
    admission = None
    if args.overload != "off":
        admission = SaturationAdmission(horizon_ticks=args.shed_horizon,
                                        mode=args.overload)
    faults = (FaultPlan.parse(args.fault_plan)
              if args.fault_plan else None)
    telemetry_on = bool(args.trace or args.metrics or args.report)
    tracer = Tracer() if telemetry_on else None
    metrics = MetricsRegistry() if telemetry_on else None
    if telemetry_on:
        DISPATCH_LOG.enabled = True
        metrics.collector(DISPATCH_LOG.prometheus_samples)
    sched = engine.streaming_scheduler(
        slice_steps=args.slice_steps, max_wait_ticks=args.max_wait_ticks,
        trunk_cache=cache, packed=not args.per_group, policy=policy,
        max_groups_per_tick=args.max_groups_per_tick,
        admission=admission, faults=faults, tracer=tracer,
        metrics=metrics, mix_samplers=args.sampler_mix > 0)

    # qos assignment: a seeded coin per request tags it interactive
    # (deadline-carrying) with probability --qos-mix, else batch
    qrng = np.random.RandomState(args.seed + 2)
    interactive = qrng.rand(len(prompts)) < args.qos_mix

    # hetero geometry: per-request shape / quality tier / solver draws.
    # Shapes derive from the model's square latent: full, half-res and
    # half-width (portrait) variants — all patch-aligned.
    hrng = np.random.RandomState(args.seed + 3)
    h, c = engine.cfg.latent_size, engine.cfg.latent_channels
    alt_shapes = [(h // 2, h // 2, c), (h // 2, h, c)]
    other = {"ddim": "dpmpp", "dpmpp": "ddim"}[engine.sage.sampler]

    def draw_axes(batch):
        shp = [alt_shapes[hrng.randint(2)] if hrng.rand() < args.shape_mix
               else (h, h, c) for _ in batch]
        tr = [("draft", "premium")[hrng.randint(2)]
              if hrng.rand() < args.tier_mix else "standard" for _ in batch]
        smp = [other if hrng.rand() < args.sampler_mix
               else engine.sage.sampler for _ in batch]
        return {"shape": shp, "tier": tr, "sampler": smp}

    t0 = time.time()
    done, now, i = [], 0.0, 0
    while i < len(prompts) or sched.pending:
        now += 1.0
        int_batch, bat_batch = [], []
        while i < len(prompts) and arrival_t[i] <= now:
            (int_batch if interactive[i] else bat_batch).append(prompts[i])
            i += 1
        if int_batch:
            sched.submit(int_batch, now=now,
                         deadline=now + args.int_deadline,
                         qos="interactive", **draw_axes(int_batch))
        if bat_batch:
            sched.submit(bat_batch, now=now, qos="batch",
                         **draw_axes(bat_batch))
        done.extend(sched.tick(now=now))
    dt = time.time() - t0

    s = sched.summary()
    hits = sum(1 for c in done if c.cache_hit)
    ok = sum(1 for c in done if c.status == "ok")
    print(f"served {ok}/{len(done)} requests in {dt:.1f}s wall "
          f"({s['ticks']:.0f} ticks, arrival rate {args.arrival_rate}/tick)")
    print(f"NFE total          = {s['nfe']:.0f}")
    print(f"NFE if independent = {s['nfe_independent']:.0f}")
    print(f"cost saving        = {s['cost_saving']:.1%}")
    print(f"latency p50 / p95  = {s['latency_p50']:.1f} / "
          f"{s['latency_p95']:.1f} ticks")
    print(f"occupancy / queue  = {s['occupancy_mean']:.2f} / "
          f"{s['queue_depth_mean']:.1f}")
    print(f"launches per tick  = {s['launches_per_tick']:.2f} "
          f"({'per-group' if args.per_group else 'packed'}, "
          f"policy {args.policy}, pad waste {s['pad_waste']:.1%})")
    if args.shape_mix > 0 or args.tier_mix > 0 or args.sampler_mix > 0:
        for tier, ts in sorted(sched.tier_stats.items()):
            print(f"  tier {tier:<9} = {ts['completed']:.0f} done, "
                  f"NFE {ts['nfe']:.0f} "
                  f"({sched.tiers[tier]} steps/request)")
        for key, b in sorted(sched.shape_stats.items()):
            print(f"  shape {key:<8} = {b['launches']:.0f} launches, "
                  f"{b['rows']:.0f} rows ({b['pad_rows']:.0f} pad)")
    if args.qos_mix > 0 or args.overload != "off" or faults is not None:
        print(f"goodput            = {s['goodput']:.0f} deadline-met "
              f"({s['goodput_per_tick']:.2f}/tick), "
              f"missed {s['deadline_missed']:.0f}")
        print(f"overload ledger    = shed {s['shed']:.0f}, degraded "
              f"{s['degraded']:.0f}, rejected_expired "
              f"{s['rejected_expired']:.0f}, backlog "
              f"{s['backlog_ticks']:.1f} ticks")
        print(f"preemption         = {s['preemptions']:.0f} preempts, "
              f"{s['resumes']:.0f} resumes")
        for q in ("interactive", "batch"):
            if f"{q}_requests" in s:
                print(f"  {q:<11} req  = {s[f'{q}_requests']:.0f} "
                      f"(ok {s.get(f'{q}_completed', 0):.0f}, "
                      f"shed {s.get(f'{q}_shed', 0):.0f}, "
                      f"p95 {s.get(f'{q}_latency_p95', 0):.1f} ticks)")
    if faults is not None:
        inj = {k: v for k, v in faults.injected.items() if v}
        print(f"fault injection    = {sum(faults.injected.values())} "
              f"injected {inj or '{}'} / "
              f"{sum(faults.queries.values())} draws; "
              f"{s['launch_faults']:.0f} launch "
              f"faults, {s['retries']:.0f} retries, {s['shed_faulted']:.0f} "
              f"shed_faulted, {s['stalled_ticks']:.0f} stalled ticks, "
              f"nfe_wasted {s['nfe_wasted']:.0f}")
    if cache is not None:
        print(f"trunk cache        = {hits} hit requests, "
              f"{s['cache_hits']:.0f} group hits "
              f"({s['cache_exact_hits']:.0f} exact, "
              f"rate {s['cache_hit_rate']:.0%}), "
              f"NFE saved {s['nfe_saved_cache']:.0f}, "
              f"{s['cache_entries']:.0f} entries / {s['cache_bytes']:.0f} B")
        print(f"cache admission    = {args.cache_admission}, "
              f"{s['cache_admission_rejects']:.0f} store rejects")
        print(f"cache index/tiers  = {s['cache_index']}, "
              f"hbm {s['cache_hbm_bytes']:.0f} B / "
              f"host {s['cache_host_bytes']:.0f} B, "
              f"{s['cache_spills']:.0f} spills, "
              f"{s['cache_promotions']:.0f} promotions")

    if tracer is not None and args.trace:
        n = tracer.export(args.trace)
        print(f"trace              = {args.trace} ({n} events, "
              f"{tracer.dropped} dropped)")
    if metrics is not None and args.metrics:
        n = metrics.export(args.metrics)
        print(f"metrics            = {args.metrics} ({n} lines)")
    if args.report:
        slo = reports.slo_report(s, counts=tracer.counts(),
                                 pending=sched.pending)
        cap = reports.capacity_report(
            s, total_steps=engine.sage.total_steps,
            share_ratio=engine.sage.share_ratio,
            group_size=engine.group_size,
            slice_steps=args.slice_steps,
            max_groups_per_tick=args.max_groups_per_tick,
            n_params=engine.cfg.n_params(),
            n_tokens=(engine.cfg.latent_size // engine.cfg.patch) ** 2)
        print(reports.format_report(slo, cap,
                                    reports.dispatch_report()))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--shared-uncond", action="store_true")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--backend", choices=["naive", "chunked", "pallas"],
                    default="naive",
                    help="attention backend (repro.kernels.dispatch)")
    ap.add_argument("--fused-step", action="store_true",
                    help="fused Pallas CFG+solver update (DDIM and dpmpp)")
    ap.add_argument("--sampler", choices=["ddim", "dpmpp"], default="ddim",
                    help="ODE solver (both have fused Pallas kernels)")
    ap.add_argument("--streaming", action="store_true",
                    help="continuous-batching scheduler + Poisson arrivals")
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean arrivals per tick (streaming mode)")
    ap.add_argument("--slice-steps", type=int, default=4,
                    help="sampler steps each in-flight group advances "
                         "per tick")
    ap.add_argument("--max-wait-ticks", type=int, default=2,
                    help="ticks an underfull group waits before launching")
    ap.add_argument("--per-group", action="store_true",
                    help="disable packed tick execution (one denoiser "
                         "launch per group per tick instead of one per "
                         "pack bucket; streaming mode)")
    ap.add_argument("--policy", choices=["eager", "pad_aware", "adaptive"],
                    default="eager",
                    help="launch policy (streaming mode): eager launches "
                         "sub-full groups at max-wait; pad_aware holds "
                         "them inside a deadline-safe window to fill "
                         "branch rows before padding them; adaptive "
                         "scales the hold budget with the observed "
                         "arrival rate")
    ap.add_argument("--hold-ticks", type=int, default=2,
                    help="extra ticks pad_aware may hold a sub-full "
                         "group past max-wait")
    ap.add_argument("--qos-mix", type=float, default=0.0,
                    help="fraction of arrivals tagged interactive "
                         "(deadline-carrying, preferred by the qos_edf "
                         "launch order); the rest are batch class "
                         "(streaming mode)")
    ap.add_argument("--int-deadline", type=float, default=8.0,
                    help="deadline (ticks after arrival) attached to "
                         "interactive requests")
    ap.add_argument("--overload", choices=["off", "shed", "degrade"],
                    default="off",
                    help="saturation admission past --shed-horizon ticks "
                         "of estimated backlog: shed rejects (accounted "
                         "status=shed), degrade admits at draft NFE "
                         "(max share bucket)")
    ap.add_argument("--shed-horizon", type=float, default=8.0,
                    help="backlog horizon (ticks) beyond which admission "
                         "sheds/degrades; interactive gets 2x headroom")
    ap.add_argument("--max-groups-per-tick", type=int, default=None,
                    help="cap on groups advanced per tick (the launch-"
                         "slot budget preemption arbitrates; default "
                         "unlimited)")
    ap.add_argument("--shape-mix", type=float, default=0.0,
                    help="fraction of arrivals requesting an alternate "
                         "latent shape (half-res or portrait variant of "
                         "the model's square latent); shape buckets pack "
                         "side by side in one tick (streaming mode)")
    ap.add_argument("--tier-mix", type=float, default=0.0,
                    help="fraction of arrivals at a non-standard quality "
                         "tier (draft or premium, 50/50): per-row step "
                         "budgets inside shared packs (streaming mode)")
    ap.add_argument("--sampler-mix", type=float, default=0.0,
                    help="fraction of arrivals using the non-default "
                         "solver; >0 enables mixed-sampler packs "
                         "(per-row ddim/dpmpp dispatch in one launch; "
                         "streaming mode)")
    ap.add_argument("--fault-plan", default="",
                    help="seeded fault injection spec, e.g. "
                         "'launch=0.1,miss=0.05,corrupt=0.02,stall=0.05,"
                         "seed=7,max=50' (streaming mode)")
    ap.add_argument("--trunk-cache", action="store_true",
                    help="cross-batch semantic trunk cache")
    ap.add_argument("--tau-trunk", type=float, default=0.95,
                    help="cosine threshold for trunk-cache hits")
    ap.add_argument("--cache-admission", choices=["always", "popularity"],
                    default="always",
                    help="trunk-cache store policy: always (LRU) or "
                         "popularity (store on Nth demand hit, evict "
                         "cold entries first)")
    ap.add_argument("--cache-index", choices=["scan", "lsh"],
                    default="scan",
                    help="trunk-cache similarity search: exact linear "
                         "scan (oracle) or sign-random-projection LSH "
                         "buckets (candidates re-verified against "
                         "tau-trunk, so hits are never false accepts)")
    ap.add_argument("--hbm-budget", type=int, default=64 * 1024 * 1024,
                    help="trunk-cache HBM working-set byte budget")
    ap.add_argument("--host-budget", type=int, default=0,
                    help="host-RAM spill-tier byte budget (0 disables "
                         "the tier: HBM overflow evicts instead of "
                         "spilling)")
    ap.add_argument("--popularity-threshold", type=int, default=2,
                    help="demand hits a centroid key needs before its "
                         "trunk earns cache bytes (popularity admission)")
    ap.add_argument("--themes", type=int, default=0,
                    help="draw prompts from this many repeated themes "
                         "(0 = all distinct) — repeated themes are what "
                         "the trunk cache exploits")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of the run "
                         "(request/group/exec lifecycle lanes; open in "
                         "Perfetto; streaming mode)")
    ap.add_argument("--metrics", default="",
                    help="write the Prometheus text exposition of all "
                         "serving metrics + kernel dispatch routes "
                         "(streaming mode)")
    ap.add_argument("--report", action="store_true",
                    help="print the joined SLO/capacity/dispatch report "
                         "(streaming mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    engine = build_engine(args)
    ds = ShapesDataset(res=16)
    if args.themes > 0:
        _, base = ds.batch(0, args.themes)
        rng = np.random.RandomState(args.seed + 1)
        prompts = [base[rng.randint(args.themes)]
                   for _ in range(args.requests)]
    else:
        _, prompts = ds.batch(0, args.requests)

    if args.streaming:
        run_streaming(engine, prompts, args)
    else:
        run_sync(engine, prompts)


if __name__ == "__main__":
    main()
