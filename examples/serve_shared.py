"""Serve batched text-to-image requests through the SAGE engine: semantic
grouping + shared sampling + adaptive branch point + (optionally) the
beyond-paper shared-uncond CFG.

    PYTHONPATH=src python examples/serve_shared.py --requests 24 --adaptive
"""
import argparse
import time

import jax
import numpy as np

from repro.config import SageConfig, get_config
from repro.data.synthetic import ShapesDataset
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.engine import SageServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--adaptive", action="store_true")
    ap.add_argument("--shared-uncond", action="store_true")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--backend", choices=["naive", "chunked", "pallas"],
                    default="naive",
                    help="attention backend (repro.kernels.dispatch)")
    ap.add_argument("--fused-step", action="store_true",
                    help="fused Pallas CFG+solver update (DDIM and dpmpp)")
    ap.add_argument("--sampler", choices=["ddim", "dpmpp"], default="ddim",
                    help="ODE solver (both have fused Pallas kernels)")
    args = ap.parse_args()

    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=args.steps, share_ratio=0.3,
                      guidance_scale=4.0, tau_min=0.3,
                      adaptive_branch=args.adaptive,
                      shared_uncond_cfg=args.shared_uncond,
                      sampler=args.sampler)
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    engine = SageServingEngine(
        cfg, sage,
        dit_params=dit.init_params(cfg, jax.random.PRNGKey(0)),
        text_params=te.init_text(jax.random.PRNGKey(1), tc),
        text_cfg=tc, group_size=4,
        attn_impl=args.backend,
        step_impl="fused" if args.fused_step else None)

    ds = ShapesDataset(res=16)
    _, prompts = ds.batch(0, args.requests)
    engine.submit(prompts)

    t0 = time.time()
    done = []
    while engine.queue:
        done.extend(engine.step(max_batch=16))
    dt = time.time() - t0

    groups = {}
    for c in done:
        groups.setdefault(c.group_id, []).append(c.prompt)
    print(f"served {len(done)} requests in {dt:.1f}s "
          f"({len(groups)} groups in last batch)")
    for gid, ps in sorted(groups.items())[:5]:
        print(f"  group {gid}: {ps}")
    print(f"NFE total          = {engine.stats['nfe']:.0f}")
    print(f"NFE if independent = {engine.stats['nfe_independent']:.0f}")
    print(f"cost saving        = {engine.cost_saving:.1%}"
          + ("  (adaptive T*)" if args.adaptive else "")
          + ("  (+shared-uncond CFG)" if args.shared_uncond else ""))


if __name__ == "__main__":
    main()
