"""End-to-end driver: train a ~100M-param DiT with the SAGE objective
(Alg. 2 / Eq. 3) for a few hundred steps on the grouped procedural corpus.

Defaults run the 100M config (158M params measured) for 200 steps — sized
for the TPU mesh; on this CPU container one step is ~200 s, so pass
--smoke for a fast sanity run (the identical code path at test size).

    PYTHONPATH=src python examples/train_sage.py --steps 200 [--smoke]
    PYTHONPATH=src python examples/train_sage.py --lora 8      # LoRA FT
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import OptimConfig, SageConfig, get_config
from repro.core import trainer
from repro.core.schedule import make_schedule
from repro.data.grouped import build_grouped_dataset
from repro.models import text_encoder as te


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--lora", type=int, default=0)
    ap.add_argument("--k-groups", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=3)
    ap.add_argument("--ckpt", default="experiments/sage_dit_ckpt")
    args = ap.parse_args()

    cfg = get_config("sage-dit-100m", smoke=args.smoke)
    sage = SageConfig(total_steps=30, share_ratio=0.3, tau_min=0.4)
    sched = make_schedule(1000)
    opt = OptimConfig(lr=3e-4 if not args.lora else 1e-3)
    res = cfg.latent_size * cfg.patch  # images decode at latent*patch here

    print(f"model={cfg.name} d={cfg.d_model} L={cfg.n_layers} "
          f"lora={args.lora}")

    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    tp = te.init_text(jax.random.PRNGKey(0), tc)

    def encode(prompts):
        toks = te.tokenize(prompts, max_len=cfg.cond_len)
        return te.encode_text(tp, tc, toks)

    gd = build_grouped_dataset(encode, n_items=128, res=res,
                               tau_min=sage.tau_min, tau_max=0.95,
                               group_max=args.group_size)
    print(f"dataset: {len(gd.prompts)} pairs, {len(gd.groups)} groups, "
          f"sizes {np.bincount([len(g) for g in gd.groups])[1:]}")

    state = trainer.init_state(cfg, opt, jax.random.PRNGKey(1),
                               lora_rank=args.lora)
    step_fn = trainer.make_sage_train_step(cfg, sage, sched, opt,
                                           lora_rank=args.lora)

    def latents(images):
        x = jnp.asarray(images, jnp.float32)
        B, H, W, C = x.shape
        p = cfg.patch
        x = x.reshape(B, H // p, p, W // p, p, C)
        x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, H // p, W // p, -1)
        return x[..., :cfg.latent_channels]

    it, losses, t0 = None, [], time.time()
    for i in range(args.steps):
        if it is None:
            it = gd.iter_batches(args.k_groups, args.group_size, seed=i)
        try:
            b = next(it)
        except StopIteration:
            it = None
            continue
        z = latents(b["images"].reshape(-1, res, res, 3)).reshape(
            args.k_groups, args.group_size, cfg.latent_size,
            cfg.latent_size, cfg.latent_channels)
        batch = {"z": z, "cond": jnp.asarray(b["cond"]),
                 "mask": jnp.asarray(b["mask"])}
        state, m = step_fn(state, batch, jax.random.PRNGKey(100 + i))
        losses.append(float(m["loss"]))
        if i % 20 == 0:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"shared={float(m['shared']):.4f} "
                  f"soft={float(m['soft']):.4f} "
                  f"branch={float(m['branch']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first 10: {np.mean(losses[:10]):.4f})")
    save_checkpoint(args.ckpt, args.steps,
                    state["lora"] if args.lora else state["params"])
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
