"""Serving-scheduler benchmark: synchronous engine vs continuous-batching
streaming vs streaming + cross-batch trunk cache, on a repeated-theme
arrival trace (the workload arXiv 2508.21032 identifies as the sweet spot
for cross-query trunk reuse).

The trace is `waves` waves of `wave_size` prompts drawn from a small theme
pool, arriving one wave per tick gap.  The sync engine serves each wave as
its own batch (it cannot share across time); the streaming scheduler runs
the same arrivals through tick-sliced segments; the cached variant
additionally skips shared phases whose group centroid hits the trunk
cache.  Rows report us-per-request wall time plus NFE / NFE-saved /
latency-percentile / occupancy derived stats — NFE is the
backend-independent number (wall us off-TPU prices the interpret-mode
call graph, see benchmarks/README.md).

Rows: serving/{sync,stream,stream_cache}/<trace>.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.config import SageConfig, get_config
from repro.data.synthetic import ShapesDataset
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.engine import SageServingEngine
from repro.serving.trunk_cache import TrunkCache

THEMES = 3
WAVE_SIZE = 4
WAVES = 3
STEPS = 6
SLICE = 3


def _trace(seed=0):
    """WAVES waves of WAVE_SIZE prompts from a THEMES-sized pool."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    rng = np.random.RandomState(seed)
    return [[base[rng.randint(THEMES)] for _ in range(WAVE_SIZE)]
            for _ in range(WAVES)]


def _engine():
    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=STEPS, share_ratio=0.33,
                      guidance_scale=3.0, tau_min=0.3)
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    return SageServingEngine(
        cfg, sage, dit_params=dit.init_params(cfg, jax.random.PRNGKey(0)),
        text_params=te.init_text(jax.random.PRNGKey(1), tc),
        text_cfg=tc, group_size=4)


def _run_sync(waves):
    eng = _engine()
    t0 = time.time()
    done = []
    for wave in waves:
        eng.submit(wave)
        done.extend(eng.step(max_batch=len(wave)))
    us = (time.time() - t0) * 1e6
    return us, len(done), dict(eng.stats), {}


def _run_stream(waves, cache):
    sched = _engine().streaming_scheduler(
        slice_steps=SLICE, max_wait_ticks=1, trunk_cache=cache)
    t0 = time.time()
    done, now = [], 0.0
    for wave in waves:
        sched.submit(wave, now=now)
        while sched.pending:              # wave gap > service time
            now += 1.0
            done.extend(sched.tick(now=now))
    us = (time.time() - t0) * 1e6
    return us, len(done), dict(sched.stats), sched.summary()


def main(rows=None):
    rows = rows if rows is not None else []
    waves = _trace()
    n_req = sum(len(w) for w in waves)
    trace = f"themes{THEMES}x{WAVES}w{WAVE_SIZE}T{STEPS}"

    us, n, stats, _ = _run_sync(waves)
    nfe_sync = stats["nfe"]
    rows.append((f"serving/sync/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"saving={1 - stats['nfe'] / stats['nfe_independent']:.3f}"))

    us, n, stats, s = _run_stream(waves, cache=None)
    rows.append((f"serving/stream/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"p50={s['latency_p50']:.1f} p95={s['latency_p95']:.1f} "
                 f"occ={s['occupancy_mean']:.2f}"))

    us, n, stats, s = _run_stream(waves, cache=TrunkCache(tau_trunk=0.9))
    assert n == n_req and stats["nfe"] < nfe_sync, (
        f"trunk-cache path must beat sync NFE: {stats['nfe']} vs {nfe_sync}")
    rows.append((f"serving/stream_cache/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"nfe_saved={stats['nfe_saved_cache']:.0f} "
                 f"vs_sync={1 - stats['nfe'] / nfe_sync:.3f} "
                 f"hits={s['cache_hits']:.0f} "
                 f"p50={s['latency_p50']:.1f} p95={s['latency_p95']:.1f}"))

    for r in rows[-3:]:
        print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
