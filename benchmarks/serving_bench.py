"""Serving-scheduler benchmark: synchronous engine vs continuous-batching
streaming vs streaming + cross-batch trunk cache, on a repeated-theme
arrival trace (the workload arXiv 2508.21032 identifies as the sweet spot
for cross-query trunk reuse).

The trace is `waves` waves of `wave_size` prompts drawn from a small theme
pool, arriving one wave per tick gap.  The sync engine serves each wave as
its own batch (it cannot share across time); the streaming scheduler runs
the same arrivals through tick-sliced segments; the cached variant
additionally skips shared phases whose group centroid hits the trunk
cache.  Rows report us-per-request wall time plus NFE / NFE-saved /
latency-percentile / occupancy derived stats — NFE is the
backend-independent number (wall us off-TPU prices the interpret-mode
call graph, see benchmarks/README.md).

The packed-vs-per-group pair runs a concurrent BURST trace instead (all
themes in flight at once, >= 3 concurrent groups): identical results and
NFE by construction (the packing parity bar), so the rows isolate the
dispatch economics — denoiser launches/tick (the packed win) and
us-per-tick wall time, plus pad_waste (the price of the static branch
width).  Launch counts are backend-independent; off-TPU the us-per-tick
gap underestimates the compiled gap, since interpret mode inflates
per-call compute cost relative to launch overhead.

The hetero_split-vs-hetero_packed pair runs a mixed-GEOMETRY burst:
three request classes with distinct themes and distinct hetero axes — a
thumbnail burst (quarter-res latents at the draft tier's step budget),
an image-set batch (full-res, standard tier, ddim) and hi-res dpmpp
singles.  The split baseline is the pre-hetero deployment shape: one
scheduler per class, every class its own launch every tick.  The merged
scheduler serves all three through heterogeneous packs — shape buckets
side by side in one tick, per-row tier step grids and row-level solver
dispatch collapsing the full-res ddim and dpmpp classes into ONE
stacked launch.  Distinct themes plus the hetero grouping compartments
make the groups (hence NFE) identical by construction, asserted exact;
the bench further asserts hetero-packed launches/tick strictly below
the split baseline — the hetero win the pack machinery exists for.

The eager-vs-pad_aware pair runs a STAGGERED trace (half-group-size
waves with an idle tick between them, so groups sit sub-full exactly
when the wait deadline fires): under the eager launch policy every group
goes out half-full — branch rows padded to the static width, each
sub-full group opening its own pack bucket — while ``pad_aware`` holds
sub-full groups inside a deadline-safe window until the next wave fills
them.  The rows report the
padding economics (``pad_waste``, ``launches_per_tick`` — both must drop
under pad_aware) plus NFE (asserted no worse: holds merge arrivals into
fuller groups, they never split work) and latency p95 (the price of the
hold, in virtual ticks).

The fifo-vs-qos_shed pair runs a seeded OVERLOAD trace (arrival rate >
service rate for OVL_TICKS ticks under a launch-slot cap, mixed QoS
classes with deadlines, then a bounded drain window that is identical
for both runs).  The FIFO baseline admits everything and serves in
arrival order, so interactive requests queue behind the batch backlog
and most deadlines blow; the QoS run (qos_edf launch order + preemption
+ saturation shedding) sheds batch work past the backlog horizon and
lets interactive claim slots.  Rows report goodput (deadline-met
completions inside the fixed window — raw completion counts reward
lateness), interactive latency p95, and shed counts; the bench asserts
the PR-6 acceptance criteria: QoS interactive p95 within 2x the
unloaded p95, and QoS goodput >= the FIFO baseline.

The cache_scan-vs-cache_lsh pairs are a lookup microbenchmark (no
scheduler): twin caches hold N unit centroids of dim D and serve the same
query stream — half near-duplicates (the hit regime the index exists
for), half independent randoms (the miss regime, which prices the full
similarity search).  Rows report the median lookup latency; derived
carries hits, mean candidates touched, and LSH recall vs the scan oracle.
The in-suite bars assert the PR-7 acceptance criteria: LSH hit-rate
within 5% of the scan oracle at every size, candidate sets sub-linear
(< 0.5 N) and LSH lookups faster than the scan at the largest population.
``python -m benchmarks.serving_bench --cache-scaling`` runs only these
rows (the CI smoke).

Rows: serving/{sync,stream,stream_cache}/<trace>,
      serving/{pergroup,packed}/<burst trace>,
      serving/{hetero_split,hetero_packed}/<mixed-geometry trace>,
      serving/{eager,pad_aware}/<staggered trace>,
      serving/{fifo,qos_shed}/<overload trace>,
      serving/{cache_scan,cache_lsh}/n<N>d<D>.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.config import SageConfig, get_config
from repro.data.synthetic import ShapesDataset
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.engine import SageServingEngine
from repro.serving.reports import attributed_columns
from repro.serving.telemetry import safe_ratio
from repro.serving.trunk_cache import TrunkCache, TrunkEntry

THEMES = 3
WAVE_SIZE = 4
WAVES = 3
STEPS = 6
SLICE = 3
BURST = 12           # one burst of BURST prompts over THEMES themes
STAG_WAVES = 8       # staggered trace: STAG_WAVES half-size waves ...
STAG_GAP = 2         # ... arriving one wave every STAG_GAP ticks
OVL_TICKS = 30       # overload trace: arrival > service for OVL_TICKS ...
OVL_WINDOW = 45      # ... measured over a fixed OVL_WINDOW tick budget
OVL_BATCH = 5        # batch prompts per tick (saturating class)
OVL_INT_EVERY = 6    # interactive burst of 2 every OVL_INT_EVERY ticks
OVL_INT_DL = 6.0     # interactive deadline (ticks after arrival)
OVL_BAT_DL = 12.0    # batch deadline (generous; FIFO still blows it)
OVL_CAP = 2          # max_groups_per_tick: the contended resource
HET_THUMBS = 4       # hetero mix: thumbnail burst (draft tier, quarter-res)
HET_SET = 4          # ... image-set batch (standard tier, full-res, ddim)
HET_HIRES = 2        # ... hi-res singles (standard tier, full-res, dpmpp)
CACHE_NS = (64, 512)     # resident entries when the lookups are timed
CACHE_DIMS = (32, 128)   # embedding dims (cond_dim-scale, CLIP-scale)
CACHE_QUERIES = 64       # near-dup queries per config (+ as many randoms)
CACHE_TAU = 0.9


def _trace(seed=0):
    """WAVES waves of WAVE_SIZE prompts from a THEMES-sized pool."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    rng = np.random.RandomState(seed)
    return [[base[rng.randint(THEMES)] for _ in range(WAVE_SIZE)]
            for _ in range(WAVES)]


def _engine():
    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=STEPS, share_ratio=0.33,
                      guidance_scale=3.0, tau_min=0.3)
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    return SageServingEngine(
        cfg, sage, dit_params=dit.init_params(cfg, jax.random.PRNGKey(0)),
        text_params=te.init_text(jax.random.PRNGKey(1), tc),
        text_cfg=tc, group_size=4)


def _run_sync(waves):
    eng = _engine()
    t0 = time.time()
    done = []
    for wave in waves:
        eng.submit(wave)
        done.extend(eng.step(max_batch=len(wave)))
    us = (time.time() - t0) * 1e6
    return us, len(done), dict(eng.stats), {}


def _run_stream(waves, cache):
    sched = _engine().streaming_scheduler(
        slice_steps=SLICE, max_wait_ticks=1, trunk_cache=cache)
    t0 = time.time()
    done, now = [], 0.0
    for wave in waves:
        sched.submit(wave, now=now)
        while sched.pending:              # wave gap > service time
            now += 1.0
            done.extend(sched.tick(now=now))
    us = (time.time() - t0) * 1e6
    return us, len(done), dict(sched.stats), sched.summary()


def _run_burst(packed):
    """All prompts arrive at t=0 (>= THEMES groups in flight together).
    The SAME scheduler drives the burst twice — jit runner caches are
    per-scheduler-instance, so only a same-instance warm pass lets the
    timed pass price steady-state ticks rather than trace+compile; stats
    are deltas over the timed pass."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    rng = np.random.RandomState(7)
    prompts = [base[rng.randint(THEMES)] for _ in range(BURST)]
    sched = _engine().streaming_scheduler(
        slice_steps=SLICE, max_wait_ticks=1, packed=packed)

    def drive(now):
        sched.submit(prompts, now=now)
        done = []
        while sched.pending:
            now += 1.0
            done.extend(sched.tick(now=now))
        return done

    drive(0.0)                            # warm pass
    before, ticks0 = dict(sched.stats), sched.ticks
    t0 = time.time()
    done = drive(100.0)
    us = (time.time() - t0) * 1e6
    ticks = sched.ticks - ticks0
    stats = {k: v - before.get(k, 0) for k, v in sched.stats.items()}
    s = dict(sched.summary(), ticks=ticks,
             launches_per_tick=safe_ratio(stats["launches"], ticks),
             pad_waste=safe_ratio(stats["pack_pad_rows"],
                                  stats["pack_rows"]))
    return us, len(done), stats, s


def _run_stagger(policy):
    """STAG_WAVES waves of group_size/2 prompts, one wave every STAG_GAP
    ticks, then drain — the workload where eager admission pays pure pad
    waste: with 1-tick patience a group is half-full exactly when its
    wait deadline fires, so eager launches it padded and the next wave
    must seed a fresh group, while pad_aware holds it one more wave and
    launches full.  Same warm-pass convention as :func:`_run_burst`."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    sched = _engine().streaming_scheduler(
        slice_steps=SLICE, max_wait_ticks=1, packed=True, policy=policy)
    wave_size = sched.group_size // 2

    def drive(now):
        done = []
        for w in range(STAG_WAVES * STAG_GAP):
            now += 1.0
            if w % STAG_GAP == 0:
                wave = [base[(w // STAG_GAP) % THEMES]] * wave_size
                sched.submit(wave, now=now)
            done.extend(sched.tick(now=now))
        while sched.pending:
            now += 1.0
            done.extend(sched.tick(now=now))
        return done

    drive(0.0)                            # warm pass
    before, ticks0 = dict(sched.stats), sched.ticks
    t0 = time.time()
    done = drive(100.0)
    us = (time.time() - t0) * 1e6
    assert len(done) == STAG_WAVES * wave_size, (
        f"stagger trace conservation: {len(done)} completions "
        f"!= {STAG_WAVES} waves x {wave_size}")
    ticks = sched.ticks - ticks0
    stats = {k: v - before.get(k, 0) for k, v in sched.stats.items()}
    s = dict(sched.summary(), ticks=ticks,
             launches_per_tick=safe_ratio(stats["launches"], ticks),
             pad_waste=safe_ratio(stats["pack_pad_rows"],
                                  stats["pack_rows"]))
    return us, len(done), stats, s


def _hetero_classes(cfg):
    """Three request classes with distinct themes (so grouping is
    identical whether they share a scheduler or not) and distinct hetero
    axes: a thumbnail burst at quarter-res draft NFE, an image-set batch
    at full-res standard ddim, and hi-res dpmpp singles."""
    _, base = ShapesDataset(res=16).batch(0, 3)
    h, c = cfg.latent_size, cfg.latent_channels
    return [
        ("thumb", [base[0]] * HET_THUMBS,
         dict(shape=(h // 2, h // 2, c), tier="draft", sampler="ddim")),
        ("set", [base[1]] * HET_SET,
         dict(shape=(h, h, c), tier="standard", sampler="ddim")),
        ("hires", [base[2]] * HET_HIRES,
         dict(shape=(h, h, c), tier="standard", sampler="dpmpp")),
    ]


def _run_hetero(merged):
    """Hetero-mix burst: the three classes arrive together and drain.
    ``merged`` serves them through ONE scheduler with mixed-sampler
    packs (shape buckets side by side, per-row tier grids, row-level
    solver dispatch); the split baseline gives each class its own
    scheduler — one bucket per class per tick, the pre-hetero deployment
    shape.  Distinct themes + hetero compartments make the groups (and
    so NFE) identical by construction; the rows isolate launches/tick.
    Same-instance warm pass as :func:`_run_burst`."""
    eng = _engine()
    classes = _hetero_classes(eng.cfg)
    kw = dict(slice_steps=SLICE, max_wait_ticks=0, packed=True)
    if merged:
        scheds = [eng.streaming_scheduler(mix_samplers=True, **kw)]
        feeds = [(scheds[0], cls) for cls in classes]
    else:
        scheds = [eng.streaming_scheduler(**kw) for _ in classes]
        feeds = list(zip(scheds, classes))

    def drive(now):
        for s, (_, prompts, axes) in feeds:
            s.submit(prompts, now=now, **axes)
        done, ticks = [], 0
        while any(s.pending for s in scheds):
            now += 1.0
            ticks += 1
            for s in scheds:
                done.extend(s.tick(now=now))
        return done, ticks

    drive(0.0)                            # warm pass
    before = [dict(s.stats) for s in scheds]
    t0 = time.time()
    done, ticks = drive(100.0)
    us = (time.time() - t0) * 1e6
    stats = {}
    for s, b in zip(scheds, before):
        for k, v in s.stats.items():
            stats[k] = stats.get(k, 0) + v - b.get(k, 0)
    s = {"ticks": ticks,
         "launches_per_tick": safe_ratio(stats["launches"], ticks),
         "pad_waste": safe_ratio(stats["pack_pad_rows"],
                                 stats["pack_rows"])}
    return us, len(done), stats, s


def _overload_sched(qos):
    """Both overload contestants share slicing, slot cap, and starvation
    bound; they differ only in the PR-6 QoS machinery under test."""
    kw = dict(slice_steps=SLICE, max_wait_ticks=1,
              max_groups_per_tick=OVL_CAP, starvation_ticks=8)
    if qos:
        kw.update(admission="shed")       # qos_edf + preempt are defaults
    else:
        kw.update(launch_order="fifo", preempt=False)
    return _engine().streaming_scheduler(**kw)


def _run_overload(qos):
    """Seeded overload trace under a fixed tick budget.  OVL_BATCH
    same-theme batch prompts arrive every tick (arrival > service under
    the OVL_CAP slot cap) plus an interactive burst of 2 every
    OVL_INT_EVERY ticks; after OVL_TICKS arrival ticks both runs get the
    SAME bounded drain window (OVL_WINDOW total), so the FIFO baseline
    cannot inflate its goodput by draining its unbounded backlog off the
    clock.  Goodput / p95 / shed are read at the window edge; leftover
    backlog is flushed untimed so the same-instance warm pass (see
    :func:`_run_burst`) starts the timed pass clean."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    theme = base[0]                       # same-theme => groups fill
    sched = _overload_sched(qos)

    def drive(now):
        done = []
        for i in range(OVL_TICKS):
            now += 1.0
            if i % OVL_INT_EVERY == 0:
                sched.submit([theme, theme], now=now,
                             deadline=now + OVL_INT_DL, qos="interactive")
            sched.submit([theme] * OVL_BATCH, now=now,
                         deadline=now + OVL_BAT_DL, qos="batch")
            done.extend(sched.tick(now=now))
        for _ in range(OVL_WINDOW - OVL_TICKS):
            if not sched.pending:
                break
            now += 1.0
            done.extend(sched.tick(now=now))
        window = dict(sched.stats), list(done)
        while sched.pending:              # untimed flush past the window
            now += 1.0
            done.extend(sched.tick(now=now))
        return window

    drive(0.0)                            # warm pass
    before, ticks0 = dict(sched.stats), sched.ticks
    t0 = time.time()
    snap, done = drive(1000.0)
    us = (time.time() - t0) * 1e6
    ticks = sched.ticks - ticks0
    stats = {k: snap[k] - before.get(k, 0) for k in snap}
    ints = sorted(c.latency for c in done
                  if c.qos == "interactive" and c.status == "ok")
    s = {"ticks": ticks,
         "goodput": stats["deadline_met"],
         "int_p95": float(np.percentile(ints, 95)) if ints else 0.0,
         "int_ok": len(ints),
         "bat_ok": sum(1 for c in done
                       if c.qos == "batch" and c.status == "ok")}
    return us, len(done), stats, s


def _run_unloaded_p95():
    """Interactive p95 with no competing load (arrival << service) on
    the QoS scheduler config — the reference point for the PR-6
    "interactive p95 within 2x unloaded" acceptance bar.  Latencies are
    virtual-time, so one deterministic pass suffices."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    theme = base[0]
    sched = _overload_sched(qos=True)
    done, now = [], 0.0
    for _ in range(5):
        sched.submit([theme, theme], now=now + 1.0,
                     deadline=now + 1.0 + OVL_INT_DL, qos="interactive")
        for _ in range(2 * OVL_INT_EVERY):   # arrival gap >> service time
            now += 1.0
            done.extend(sched.tick(now=now))
    while sched.pending:
        now += 1.0
        done.extend(sched.tick(now=now))
    lats = [c.latency for c in done]
    return float(np.percentile(lats, 95))


def _unit_rows(v):
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def _run_cache_lookup(n, dim):
    """Twin caches (scan oracle / LSH) with n resident unit centroids of
    the given dim, timed on the same query stream: CACHE_QUERIES
    rejection-sampled near-duplicates (exact cosine to their source
    >= CACHE_TAU, so the oracle hits every one) + as many independent
    randoms.  Returns per-index {us (median lookup), hits, cand (mean
    centroids touched per similarity search)}."""
    rng = np.random.RandomState(n * 1000 + dim)
    pop = _unit_rows(rng.randn(n, dim).astype(np.float32))
    shape = (1, 4, 4, 3)
    z = np.zeros(shape, np.float32)
    caches = {"scan": TrunkCache(tau_trunk=CACHE_TAU, index="scan"),
              "lsh": TrunkCache(tau_trunk=CACHE_TAU, index="lsh")}
    for c in pop:
        for cache in caches.values():
            cache.insert(TrunkEntry(z=z, eps_prev=None, step_idx=2,
                                    beta_bucket=0.5, rng_fold=0,
                                    centroid=c, cfg_key="bench"),
                         shape=shape)
    # per-component noise sized so the expected cosine sits just above
    # tau (see tests/test_ann_index.py): the rejection loop terminates
    # quickly at every dim
    scale = 0.5 * np.sqrt(2.0 * (1.0 - CACHE_TAU) / dim)
    near = []
    while len(near) < CACHE_QUERIES:
        i = rng.randint(n)
        q = _unit_rows(pop[i] + scale * rng.randn(dim).astype(np.float32))
        if float(pop[i] @ q) >= CACHE_TAU:
            near.append(q)
    queries = near + list(_unit_rows(
        rng.randn(CACHE_QUERIES, dim).astype(np.float32)))

    out = {}
    for name, cache in caches.items():
        cache.lookup(queries[0], 0.5, "bench", shape)  # warm (planes jit)
        lat, hits = [], 0
        for q in queries:
            t0 = time.perf_counter()
            hit = cache.lookup(q, 0.5, "bench", shape)
            lat.append(time.perf_counter() - t0)
            hits += hit is not None
        idx = cache.index
        cand = (idx.mean_candidates if hasattr(idx, "mean_candidates")
                else float(n))
        out[name] = {"us": float(np.median(lat) * 1e6), "hits": hits,
                     "cand": cand}
    return out


def _run_cache_scaling(rows):
    """The cache-scaling grid: scan-vs-LSH lookup rows across entry
    counts and embedding dims, with the PR-7 acceptance bars asserted
    in-suite so the BENCH snapshot gates them in CI."""
    top = (max(CACHE_NS), max(CACHE_DIMS))
    for n in CACHE_NS:
        for dim in CACHE_DIMS:
            r = _run_cache_lookup(n, dim)
            recall = r["lsh"]["hits"] / max(r["scan"]["hits"], 1)
            # acceptance: LSH hit-rate within 5% of the scan oracle —
            # the index may only lose hits, and not many
            assert r["lsh"]["hits"] >= 0.95 * r["scan"]["hits"], (
                f"cache n={n} d={dim}: lsh hits {r['lsh']['hits']} < 95% "
                f"of scan {r['scan']['hits']}")
            assert r["lsh"]["hits"] <= r["scan"]["hits"], (
                "LSH can never hit where the oracle misses")
            if (n, dim) == top:
                # sub-linearity where it matters: at the largest
                # population the similarity search must touch a small
                # fraction of the entries and beat the scan's wall time
                # (python-loop over all N vs one projection + a short
                # candidate list — a multiple-x margin, safe to time)
                assert r["lsh"]["cand"] < 0.5 * n, (
                    f"LSH candidates {r['lsh']['cand']:.1f} not sub-linear "
                    f"at n={n}")
                assert r["lsh"]["us"] < r["scan"]["us"], (
                    f"LSH lookup {r['lsh']['us']:.0f}us not faster than "
                    f"scan {r['scan']['us']:.0f}us at n={n}")
            rows.append((f"serving/cache_scan/n{n}d{dim}",
                         r["scan"]["us"],
                         f"hits={r['scan']['hits']} cand={n}"))
            rows.append((f"serving/cache_lsh/n{n}d{dim}",
                         r["lsh"]["us"],
                         f"hits={r['lsh']['hits']} "
                         f"cand={r['lsh']['cand']:.1f} "
                         f"recall={recall:.3f}"))
    return rows


def main(rows=None):
    rows = rows if rows is not None else []
    waves = _trace()
    n_req = sum(len(w) for w in waves)
    trace = f"themes{THEMES}x{WAVES}w{WAVE_SIZE}T{STEPS}"

    us, n, stats, _ = _run_sync(waves)
    nfe_sync = stats["nfe"]
    rows.append((f"serving/sync/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"saving={1 - stats['nfe'] / stats['nfe_independent']:.3f}"))

    # telemetry-attributed columns (reports.attributed_columns): extra
    # k=v tokens only — run.py --check pins row names and nfe=, so the
    # attribution never perturbs the regression gate
    us, n, stats, s = _run_stream(waves, cache=None)
    rows.append((f"serving/stream/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"p50={s['latency_p50']:.1f} p95={s['latency_p95']:.1f} "
                 f"occ={s['occupancy_mean']:.2f} "
                 + attributed_columns(s)))

    us, n, stats, s = _run_stream(waves, cache=TrunkCache(tau_trunk=0.9))
    assert n == n_req and stats["nfe"] < nfe_sync, (
        f"trunk-cache path must beat sync NFE: {stats['nfe']} vs {nfe_sync}")
    rows.append((f"serving/stream_cache/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"nfe_saved={stats['nfe_saved_cache']:.0f} "
                 f"vs_sync={1 - stats['nfe'] / nfe_sync:.3f} "
                 f"hits={s['cache_hits']:.0f} "
                 f"p50={s['latency_p50']:.1f} p95={s['latency_p95']:.1f} "
                 + attributed_columns(s)))

    # packed vs per-group dispatch economics on a concurrent burst
    btrace = f"burst{BURST}x{THEMES}T{STEPS}"
    us_g, n_g, stats_g, s_g = _run_burst(packed=False)
    rows.append((f"serving/pergroup/{btrace}", us_g / s_g["ticks"],
                 f"launches_per_tick={s_g['launches_per_tick']:.2f} "
                 f"launches={stats_g['launches']:.0f} "
                 f"nfe={stats_g['nfe']:.0f}"))
    us_p, n_p, stats_p, s_p = _run_burst(packed=True)
    assert n_p == n_g == BURST
    assert stats_p["nfe"] == stats_g["nfe"], "packing must not change NFE"
    assert s_p["launches_per_tick"] < s_g["launches_per_tick"], (
        f"packed must reduce launches/tick: {s_p['launches_per_tick']} vs "
        f"{s_g['launches_per_tick']}")
    rows.append((f"serving/packed/{btrace}", us_p / s_p["ticks"],
                 f"launches_per_tick={s_p['launches_per_tick']:.2f} "
                 f"launches={stats_p['launches']:.0f} "
                 f"pad_waste={s_p['pad_waste']:.3f} "
                 f"vs_pergroup_launches="
                 f"{stats_p['launches'] / stats_g['launches']:.2f}x "
                 f"nfe={stats_p['nfe']:.0f}"))

    # hetero mix: one mixed-geometry scheduler vs per-class split
    htrace = (f"mix{HET_THUMBS}t{HET_SET}s{HET_HIRES}hT{STEPS}")
    us_s, n_s, stats_s, s_s = _run_hetero(merged=False)
    rows.append((f"serving/hetero_split/{htrace}", us_s / s_s["ticks"],
                 f"launches_per_tick={s_s['launches_per_tick']:.2f} "
                 f"launches={stats_s['launches']:.0f} "
                 f"pad_waste={s_s['pad_waste']:.3f} "
                 f"nfe={stats_s['nfe']:.0f}"))
    us_h, n_h, stats_h, s_h = _run_hetero(merged=True)
    assert n_h == n_s == HET_THUMBS + HET_SET + HET_HIRES
    assert stats_h["nfe"] == stats_s["nfe"], (
        f"hetero packing must not change NFE: {stats_h['nfe']} vs "
        f"{stats_s['nfe']}")
    assert s_h["launches_per_tick"] < s_s["launches_per_tick"], (
        f"hetero-packed must reduce launches/tick vs per-class split: "
        f"{s_h['launches_per_tick']} vs {s_s['launches_per_tick']}")
    rows.append((f"serving/hetero_packed/{htrace}", us_h / s_h["ticks"],
                 f"launches_per_tick={s_h['launches_per_tick']:.2f} "
                 f"launches={stats_h['launches']:.0f} "
                 f"pad_waste={s_h['pad_waste']:.3f} "
                 f"vs_split_launches="
                 f"{stats_h['launches'] / stats_s['launches']:.2f}x "
                 f"nfe={stats_h['nfe']:.0f}"))

    # eager vs pad-aware launch policy on a staggered-arrival trace
    strace = f"stag{STAG_WAVES}w2g{STAG_GAP}T{STEPS}"
    us_e, n_e, stats_e, s_e = _run_stagger("eager")
    rows.append((f"serving/eager/{strace}", us_e / s_e["ticks"],
                 f"launches_per_tick={s_e['launches_per_tick']:.2f} "
                 f"pad_waste={s_e['pad_waste']:.3f} "
                 f"nfe={stats_e['nfe']:.0f} "
                 f"p95={s_e['latency_p95']:.1f}"))
    us_a, n_a, stats_a, s_a = _run_stagger("pad_aware")
    assert n_a == n_e
    assert stats_a["nfe"] <= stats_e["nfe"], (
        f"pad_aware must not spend more NFE: {stats_a['nfe']} vs "
        f"{stats_e['nfe']}")
    assert s_a["pad_waste"] < s_e["pad_waste"], (
        f"pad_aware must reduce pad waste: {s_a['pad_waste']} vs "
        f"{s_e['pad_waste']}")
    assert s_a["launches_per_tick"] < s_e["launches_per_tick"], (
        f"pad_aware must reduce launches/tick: {s_a['launches_per_tick']} "
        f"vs {s_e['launches_per_tick']}")
    rows.append((f"serving/pad_aware/{strace}", us_a / s_a["ticks"],
                 f"launches_per_tick={s_a['launches_per_tick']:.2f} "
                 f"pad_waste={s_a['pad_waste']:.3f} "
                 f"nfe={stats_a['nfe']:.0f} "
                 f"p95={s_a['latency_p95']:.1f} "
                 f"vs_eager_pad={s_a['pad_waste'] - s_e['pad_waste']:+.3f}"))

    # FIFO vs QoS+shedding on a seeded overload trace (PR-6 acceptance)
    otrace = (f"ovl{OVL_TICKS}x{OVL_BATCH}w{OVL_WINDOW}T{STEPS}")
    unloaded_p95 = _run_unloaded_p95()
    us_f, n_f, stats_f, s_f = _run_overload(qos=False)
    rows.append((f"serving/fifo/{otrace}", us_f / s_f["ticks"],
                 f"goodput={s_f['goodput']:.0f} "
                 f"int_p95={s_f['int_p95']:.1f} "
                 f"missed={stats_f['deadline_missed']:.0f} "
                 f"nfe={stats_f['nfe']:.0f}"))
    us_q, n_q, stats_q, s_q = _run_overload(qos=True)
    assert s_q["int_p95"] <= 2.0 * unloaded_p95, (
        f"QoS interactive p95 must stay within 2x unloaded under "
        f"overload: {s_q['int_p95']} vs 2x{unloaded_p95}")
    assert s_q["goodput"] >= s_f["goodput"], (
        f"QoS+shedding goodput must be >= FIFO baseline: "
        f"{s_q['goodput']} vs {s_f['goodput']}")
    assert (stats_q["shed"] > 0 and s_q["int_ok"] > 0
            and s_q["bat_ok"] > 0), "overload trace must shed yet serve"
    rows.append((f"serving/qos_shed/{otrace}", us_q / s_q["ticks"],
                 f"goodput={s_q['goodput']:.0f} "
                 f"int_p95={s_q['int_p95']:.1f} "
                 f"unl_p95={unloaded_p95:.1f} "
                 f"shed={stats_q['shed']:.0f} "
                 f"preempt={stats_q['preemptions']:.0f} "
                 f"vs_fifo_goodput="
                 f"{s_q['goodput'] / max(s_f['goodput'], 1):.2f}x "
                 f"nfe={stats_q['nfe']:.0f}"))

    # scan-vs-LSH cache lookup scaling grid (PR-7 acceptance bars)
    n_before = len(rows)
    _run_cache_scaling(rows)

    for r in rows[-(11 + len(rows) - n_before):]:
        print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-scaling", action="store_true",
                    help="run only the cache-scaling lookup rows "
                         "(fast; the CI smoke)")
    if ap.parse_args().cache_scaling:
        for r in _run_cache_scaling([]):
            print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
    else:
        main()
