"""Serving-scheduler benchmark: synchronous engine vs continuous-batching
streaming vs streaming + cross-batch trunk cache, on a repeated-theme
arrival trace (the workload arXiv 2508.21032 identifies as the sweet spot
for cross-query trunk reuse).

The trace is `waves` waves of `wave_size` prompts drawn from a small theme
pool, arriving one wave per tick gap.  The sync engine serves each wave as
its own batch (it cannot share across time); the streaming scheduler runs
the same arrivals through tick-sliced segments; the cached variant
additionally skips shared phases whose group centroid hits the trunk
cache.  Rows report us-per-request wall time plus NFE / NFE-saved /
latency-percentile / occupancy derived stats — NFE is the
backend-independent number (wall us off-TPU prices the interpret-mode
call graph, see benchmarks/README.md).

The packed-vs-per-group pair runs a concurrent BURST trace instead (all
themes in flight at once, >= 3 concurrent groups): identical results and
NFE by construction (the packing parity bar), so the rows isolate the
dispatch economics — denoiser launches/tick (the packed win) and
us-per-tick wall time, plus pad_waste (the price of the static branch
width).  Launch counts are backend-independent; off-TPU the us-per-tick
gap underestimates the compiled gap, since interpret mode inflates
per-call compute cost relative to launch overhead.

Rows: serving/{sync,stream,stream_cache}/<trace>,
      serving/{pergroup,packed}/<burst trace>.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.config import SageConfig, get_config
from repro.data.synthetic import ShapesDataset
from repro.models import dit
from repro.models import text_encoder as te
from repro.serving.engine import SageServingEngine
from repro.serving.trunk_cache import TrunkCache

THEMES = 3
WAVE_SIZE = 4
WAVES = 3
STEPS = 6
SLICE = 3
BURST = 12           # one burst of BURST prompts over THEMES themes


def _trace(seed=0):
    """WAVES waves of WAVE_SIZE prompts from a THEMES-sized pool."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    rng = np.random.RandomState(seed)
    return [[base[rng.randint(THEMES)] for _ in range(WAVE_SIZE)]
            for _ in range(WAVES)]


def _engine():
    cfg = get_config("sage-dit", smoke=True)
    sage = SageConfig(total_steps=STEPS, share_ratio=0.33,
                      guidance_scale=3.0, tau_min=0.3)
    tc = te.text_cfg(dim=cfg.cond_dim, layers=2)
    return SageServingEngine(
        cfg, sage, dit_params=dit.init_params(cfg, jax.random.PRNGKey(0)),
        text_params=te.init_text(jax.random.PRNGKey(1), tc),
        text_cfg=tc, group_size=4)


def _run_sync(waves):
    eng = _engine()
    t0 = time.time()
    done = []
    for wave in waves:
        eng.submit(wave)
        done.extend(eng.step(max_batch=len(wave)))
    us = (time.time() - t0) * 1e6
    return us, len(done), dict(eng.stats), {}


def _run_stream(waves, cache):
    sched = _engine().streaming_scheduler(
        slice_steps=SLICE, max_wait_ticks=1, trunk_cache=cache)
    t0 = time.time()
    done, now = [], 0.0
    for wave in waves:
        sched.submit(wave, now=now)
        while sched.pending:              # wave gap > service time
            now += 1.0
            done.extend(sched.tick(now=now))
    us = (time.time() - t0) * 1e6
    return us, len(done), dict(sched.stats), sched.summary()


def _run_burst(packed):
    """All prompts arrive at t=0 (>= THEMES groups in flight together).
    The SAME scheduler drives the burst twice — jit runner caches are
    per-scheduler-instance, so only a same-instance warm pass lets the
    timed pass price steady-state ticks rather than trace+compile; stats
    are deltas over the timed pass."""
    _, base = ShapesDataset(res=16).batch(0, THEMES)
    rng = np.random.RandomState(7)
    prompts = [base[rng.randint(THEMES)] for _ in range(BURST)]
    sched = _engine().streaming_scheduler(
        slice_steps=SLICE, max_wait_ticks=1, packed=packed)

    def drive(now):
        sched.submit(prompts, now=now)
        done = []
        while sched.pending:
            now += 1.0
            done.extend(sched.tick(now=now))
        return done

    drive(0.0)                            # warm pass
    before, ticks0 = dict(sched.stats), sched.ticks
    t0 = time.time()
    done = drive(100.0)
    us = (time.time() - t0) * 1e6
    ticks = sched.ticks - ticks0
    stats = {k: v - before.get(k, 0) for k, v in sched.stats.items()}
    s = dict(sched.summary(), ticks=ticks,
             launches_per_tick=stats["launches"] / ticks,
             pad_waste=(stats["pack_pad_rows"] / stats["pack_rows"]
                        if stats["pack_rows"] else 0.0))
    return us, len(done), stats, s


def main(rows=None):
    rows = rows if rows is not None else []
    waves = _trace()
    n_req = sum(len(w) for w in waves)
    trace = f"themes{THEMES}x{WAVES}w{WAVE_SIZE}T{STEPS}"

    us, n, stats, _ = _run_sync(waves)
    nfe_sync = stats["nfe"]
    rows.append((f"serving/sync/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"saving={1 - stats['nfe'] / stats['nfe_independent']:.3f}"))

    us, n, stats, s = _run_stream(waves, cache=None)
    rows.append((f"serving/stream/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"p50={s['latency_p50']:.1f} p95={s['latency_p95']:.1f} "
                 f"occ={s['occupancy_mean']:.2f}"))

    us, n, stats, s = _run_stream(waves, cache=TrunkCache(tau_trunk=0.9))
    assert n == n_req and stats["nfe"] < nfe_sync, (
        f"trunk-cache path must beat sync NFE: {stats['nfe']} vs {nfe_sync}")
    rows.append((f"serving/stream_cache/{trace}", us / n,
                 f"nfe={stats['nfe']:.0f} "
                 f"nfe_saved={stats['nfe_saved_cache']:.0f} "
                 f"vs_sync={1 - stats['nfe'] / nfe_sync:.3f} "
                 f"hits={s['cache_hits']:.0f} "
                 f"p50={s['latency_p50']:.1f} p95={s['latency_p95']:.1f}"))

    # packed vs per-group dispatch economics on a concurrent burst
    btrace = f"burst{BURST}x{THEMES}T{STEPS}"
    us_g, n_g, stats_g, s_g = _run_burst(packed=False)
    rows.append((f"serving/pergroup/{btrace}", us_g / s_g["ticks"],
                 f"launches_per_tick={s_g['launches_per_tick']:.2f} "
                 f"launches={stats_g['launches']:.0f} "
                 f"nfe={stats_g['nfe']:.0f}"))
    us_p, n_p, stats_p, s_p = _run_burst(packed=True)
    assert n_p == n_g == BURST
    assert stats_p["nfe"] == stats_g["nfe"], "packing must not change NFE"
    assert s_p["launches_per_tick"] < s_g["launches_per_tick"], (
        f"packed must reduce launches/tick: {s_p['launches_per_tick']} vs "
        f"{s_g['launches_per_tick']}")
    rows.append((f"serving/packed/{btrace}", us_p / s_p["ticks"],
                 f"launches_per_tick={s_p['launches_per_tick']:.2f} "
                 f"launches={stats_p['launches']:.0f} "
                 f"pad_waste={s_p['pad_waste']:.3f} "
                 f"vs_pergroup_launches="
                 f"{stats_p['launches'] / stats_g['launches']:.2f}x "
                 f"nfe={stats_p['nfe']:.0f}"))

    for r in rows[-5:]:
        print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
