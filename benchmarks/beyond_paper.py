"""Beyond-paper extensions, quality-validated:

* shared-uncond CFG — the uncond eval amortised per group (saving jumps
  12.7 -> 38 % at beta=20 %); does quality survive?
* DPM-Solver++(2M) under shared sampling — solver orthogonality: the
  paper's scheme composes with faster solvers.
"""
from __future__ import annotations

import time

from benchmarks import common


def main(rows=None):
    rows = rows if rows is not None else []
    params = common.MODELS["sage_ft"]()
    cases = [
        ("baseline_b30", dict(beta=0.3)),
        ("shared_uncond_b30", dict(beta=0.3, shared_uncond=True)),
        ("dpmpp_b30", dict(beta=0.3, sampler="dpmpp")),
        ("dpmpp15_b30", dict(beta=0.3, sampler="dpmpp", total_steps=15)),
        ("ddim15_b30", dict(beta=0.3, total_steps=15)),
    ]
    for name, kw in cases:
        t0 = time.time()
        m = common.evaluate_scheme(params, **kw)
        dt = (time.time() - t0) * 1e6
        rows.append((f"beyond/sage_ft/{name}", dt,
                     f"fd={m['fd']:.2f};clip={m['clip']:.4f};"
                     f"div={m['div']:.4f};save={m['cost_saving']:.3f}"))
        print(f"{rows[-1][0]},{dt:.0f},{rows[-1][2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
