"""Analytic cost-saving accounting (paper Table 1 rightmost column).

Validates the NFE formula against the paper's reported savings for its
group-size distribution (2-5 members, mean ~2.9 given 50k groups /
MS-COCO cliques), and reports the beyond-paper shared-uncond CFG savings.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.grouping import cost_saving

PAPER = {0.2: 0.127, 0.3: 0.191, 0.4: 0.255}


def synth_groups(m=1000, mean_size=2.75, seed=0):
    rng = np.random.RandomState(seed)
    sizes = rng.choice([2, 3, 4, 5], size=m,
                       p=[0.55, 0.25, 0.12, 0.08])
    groups, i = [], 0
    for s in sizes:
        groups.append(list(range(i, i + s)))
        i += s
    return groups


def main(rows=None):
    rows = rows if rows is not None else []
    groups = synth_groups()
    for beta, paper_val in PAPER.items():
        t0 = time.time()
        ts = int(round(30 * (1 - beta)))
        ours = cost_saving(groups, 30, ts)["saving"]
        ours_su = cost_saving(groups, 30, ts, shared_uncond=True)["saving"]
        dt = (time.time() - t0) * 1e6
        rows.append((f"cost_model/beta{int(beta*100)}", dt,
                     f"saving={ours:.3f};paper={paper_val:.3f};"
                     f"shared_uncond={ours_su:.3f}"))
        print(f"{rows[-1][0]},{dt:.0f},{rows[-1][2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
