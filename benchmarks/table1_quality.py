"""Paper Table 1: Pre-trained / Standard FT / SAGE FT under independent and
shared sampling at beta in {20, 30, 40}%.  Emits one CSV row per cell."""
from __future__ import annotations

import time

from benchmarks import common


def main(rows=None):
    rows = rows if rows is not None else []
    schemes = [("independent", 0.0), ("shared_b20", 0.2),
               ("shared_b30", 0.3), ("shared_b40", 0.4)]
    for model_name, model_fn in common.MODELS.items():
        params = model_fn()
        for scheme, beta in schemes:
            t0 = time.time()
            m = common.evaluate_scheme(params, beta)
            dt = (time.time() - t0) * 1e6
            rows.append((f"table1/{model_name}/{scheme}", dt,
                         f"fd={m['fd']:.2f};clip={m['clip']:.4f};"
                         f"div={m['div']:.4f};save={m['cost_saving']:.3f}"))
            print(f"{rows[-1][0]},{dt:.0f},{rows[-1][2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
