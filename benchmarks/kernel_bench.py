"""Kernel micro-benchmarks (interpret mode on CPU: correctness-shaped
timings; TPU wall-clock comes from the roofline terms in EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import samplers
from repro.core.guidance import cfg_combine
from repro.core.schedule import make_schedule
from repro.kernels.ddim_step.ops import fused_cfg_ddim_step
from repro.kernels.dispatch import resolve_interpret
from repro.kernels.dpmpp_step.ops import fused_cfg_dpmpp_step
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.group_mean.ops import masked_group_mean


def _time(fn, *args, n=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def main(rows=None):
    rows = rows if rows is not None else []
    key = jax.random.PRNGKey(0)
    mode = f"interpret={resolve_interpret('auto')}"

    z, eu, ec = (jax.random.normal(jax.random.fold_in(key, i),
                                   (8, 64, 64, 4)) for i in range(3))
    us = _time(fused_cfg_ddim_step, z, eu, ec, 7.5, 0.7, 0.714, 0.9, 0.436)
    rows.append(("kernel/ddim_step/8x64x64x4", us, mode))

    x = jax.random.normal(key, (8, 5, 32, 256))
    m = jnp.ones((8, 5))
    us = _time(masked_group_mean, x, m, n=2)
    rows.append(("kernel/group_mean/8x5x32x256", us, mode))

    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (2, 256, 4, 64)) for i in range(3))
    us = _time(flash_attention, q, k, v, n=2)
    rows.append(("kernel/flash_attention/2x256x4x64", us, mode))

    # sliding window: K grid trimmed to the blocks the window touches
    qw, kw, vw = (jax.random.normal(jax.random.fold_in(key, i),
                                    (1, 512, 4, 64)) for i in range(3))
    us = _time(flash_attention, qw, kw, vw, window=128, n=2)
    rows.append(("kernel/flash_attention_w128/1x512x4x64", us, mode))

    # head_dim=256: two-lane-tile D variant
    qd, kd, vd = (jax.random.normal(jax.random.fold_in(key, i),
                                    (1, 256, 2, 256)) for i in range(3))
    us = _time(flash_attention, qd, kd, vd, n=2)
    rows.append(("kernel/flash_attention_d256/1x256x2x256", us, mode))

    # dpmpp fused kernel vs the jnp reference composition
    sched = make_schedule(1000)
    zs = [jax.random.normal(jax.random.fold_in(key, 20 + i), (8, 64, 64, 4))
          for i in range(4)]
    sc = samplers.dpmpp_scalars(sched, 700, 466, 933)

    def dpmpp_ref(z, eu, ec, ep):
        eps = cfg_combine(eu, ec, 7.5)
        return samplers.dpmpp_2m_step(sched, z, 700, 466, eps, ep, 933,
                                      clip_x0=3.0), eps

    us = _time(fused_cfg_dpmpp_step, *zs, 7.5, *sc, False, clip_x0=3.0)
    rows.append(("kernel/dpmpp_step_fused/8x64x64x4", us, mode))
    us = _time(jax.jit(dpmpp_ref), *zs)
    rows.append(("kernel/dpmpp_step_reference/8x64x64x4", us, mode))

    for r in rows[-7:]:
        print(f"{r[0]},{r[1]:.0f},{r[2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
