"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig3,...]

Prints ``name,us_per_call,derived`` CSV.  Quality benches train/cache the
three Table-1 models on first run (experiments/bench_cache/)."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (beyond_paper, cost_model, fig3_similarity,
                            fig4_shared_steps, kernel_bench, roofline_report,
                            sampler_e2e, serving_bench, table1_quality)
    suites = {
        "cost_model": cost_model.main,
        "kernels": kernel_bench.main,
        "sampler": sampler_e2e.main,
        "serving": serving_bench.main,
        "roofline": roofline_report.main,
        "table1": table1_quality.main,
        "fig3": fig3_similarity.main,
        "fig4": fig4_shared_steps.main,
        "beyond": beyond_paper.main,
    }
    print("name,us_per_call,derived")
    rows = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn(rows)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            raise
        print(f"# suite {name} done in {time.time()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
