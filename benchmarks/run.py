"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig3,...]

Prints ``name,us_per_call,derived`` CSV.  Quality benches train/cache the
three Table-1 models on first run (experiments/bench_cache/).

Regression gate (the CI ``bench-regression`` job):

    PYTHONPATH=src python -m benchmarks.run --check benchmarks/BENCH_4.json \
        --tol 50

re-runs the suites the baseline snapshot covers and fails (exit 1) if any
row regressed: ``nfe=`` in ``derived`` must match EXACTLY (NFE is the
backend-independent work ledger — any drift is a correctness bug, not
noise), and ``us`` must stay within ``--tol`` percent of the baseline
(wall time prices the interpret-mode call graph off-TPU; the tolerance
absorbs runner jitter, the exact-NFE bar does the real gating).  Rows
missing from the current run fail too.  ``--json PATH`` additionally
writes the rows as a BENCH_N-style snapshot fragment (the nightly
workflow uploads it as an artifact)."""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

Row = Tuple[str, float, str]


def _derived_map(derived: str) -> Dict[str, str]:
    """Parse 'k1=v1 k2=v2 ...' derived strings; bare tokens are skipped."""
    out = {}
    for tok in derived.split():
        if "=" in tok:
            k, v = tok.split("=", 1)
            out[k] = v
    return out


def check_rows(baseline: dict, rows: List[Row], tol_pct: float
               ) -> List[str]:
    """Compare a current run against a committed BENCH_N snapshot.

    Returns a list of human-readable regression messages (empty = pass):
    missing rows, any ``nfe=`` mismatch (exact), and ``us`` above
    ``baseline * (1 + tol_pct/100)``.  Faster-than-baseline is never a
    failure."""
    current = {name: (us, derived) for name, us, derived in rows}
    problems = []
    for brow in baseline["rows"]:
        name = brow["name"]
        if name not in current:
            problems.append(f"{name}: row missing from current run")
            continue
        us, derived = current[name]
        b_derived = _derived_map(brow["derived"])
        c_derived = _derived_map(derived)
        if "nfe" in b_derived:
            if float(c_derived.get("nfe", "nan")) != float(b_derived["nfe"]):
                problems.append(
                    f"{name}: NFE {c_derived.get('nfe')} != baseline "
                    f"{b_derived['nfe']} (exact match required)")
        limit = brow["us"] * (1.0 + tol_pct / 100.0)
        if us > limit:
            problems.append(
                f"{name}: {us:.1f} us > {limit:.1f} us "
                f"(baseline {brow['us']:.1f} + {tol_pct:g}% tol)")
    return problems


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated suite names to run")
    ap.add_argument("--check", default="",
                    help="BENCH_N.json baseline to gate against (runs the "
                         "suites its rows cover; exit 1 on regression)")
    ap.add_argument("--tol", type=float, default=50.0,
                    help="us tolerance (percent) for --check; NFE is "
                         "always exact")
    ap.add_argument("--json", default="",
                    help="write the rows as a JSON snapshot fragment")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (beyond_paper, cost_model, fig3_similarity,
                            fig4_shared_steps, kernel_bench, roofline_report,
                            sampler_e2e, serving_bench, table1_quality)
    suites = {
        "cost_model": cost_model.main,
        "kernels": kernel_bench.main,
        "sampler": sampler_e2e.main,
        "serving": serving_bench.main,
        "roofline": roofline_report.main,
        "table1": table1_quality.main,
        "fig3": fig3_similarity.main,
        "fig4": fig4_shared_steps.main,
        "beyond": beyond_paper.main,
    }

    baseline = None
    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)
        needed = {r["name"].split("/")[0] for r in baseline["rows"]}
        unknown = needed - set(suites)
        if unknown:
            print(f"--check baseline names unknown suites: {unknown}",
                  file=sys.stderr)
            return 2
        only = needed if only is None else (only & needed)
        if not only:
            print(f"--only {args.only!r} selects none of the baseline's "
                  f"suites ({sorted(needed)}) — nothing to gate",
                  file=sys.stderr)
            return 2
        print(f"# regression gate vs {args.check} "
              f"(suites: {','.join(sorted(only))}, tol {args.tol:g}%)",
              file=sys.stderr)

    print("name,us_per_call,derived")
    rows: List[Row] = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn(rows)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            raise
        print(f"# suite {name} done in {time.time()-t0:.1f}s",
              file=sys.stderr)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us": u, "derived": d}
                                for n, u, d in rows]}, f, indent=1)
        print(f"# rows written to {args.json}", file=sys.stderr)

    if baseline is not None:
        problems = check_rows(baseline, rows, args.tol)
        for p in problems:
            print(f"::error::bench regression: {p}", file=sys.stderr)
        if problems:
            return 1
        print(f"# bench gate PASS: {len(baseline['rows'])} rows within "
              f"{args.tol:g}% (NFE exact)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
