"""End-to-end sampler benchmark: shared_sample on the naive jnp backend vs
the Pallas backend (attn_impl="pallas" + step_impl="fused"), reported as
µs per sampler step normalized by NFE.

Off-TPU this exercises the kernels in interpret mode (correctness-shaped
timings that track the call graph, not device wall-clock); on TPU the same
rows time the compiled kernels.  Rows: name,us_per_nfe,derived."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.config import SageConfig, get_config, replace
from repro.core.schedule import make_schedule
from repro.core.shared_sampling import shared_sample
from repro.kernels.dispatch import resolve_interpret
from repro.models import dit


def _time(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6, out


def main(rows=None):
    rows = rows if rows is not None else []
    cfg = get_config("sage-dit", smoke=True)
    sched = make_schedule(1000)
    key = jax.random.PRNGKey(0)
    params = dit.init_params(cfg, key)

    K, N = 2, 4
    sage = SageConfig(total_steps=8, share_ratio=0.25, guidance_scale=7.5,
                      shared_uncond_cfg=True)
    cond = jax.random.normal(jax.random.fold_in(key, 1),
                             (K, N, cfg.cond_len, cfg.cond_dim))
    mask = jnp.ones((K, N))
    null = jnp.zeros((cfg.cond_len, cfg.cond_dim))
    shape = (cfg.latent_size, cfg.latent_size, cfg.latent_channels)
    mode = "interpret" if resolve_interpret("auto") else "compiled"

    dpmpp = replace(sage, sampler="dpmpp")
    variants = {
        "naive": (cfg, sage),
        "pallas": (replace(cfg, attn_impl="pallas"),
                   replace(sage, step_impl="fused")),
        # dpmpp fused-vs-reference pair: same attention backend, so the
        # row delta isolates the fused CFG+DPM-Solver++(2M) step kernel
        "dpmpp_ref": (cfg, dpmpp),
        "dpmpp_fused": (cfg, replace(dpmpp, step_impl="fused")),
    }
    for name, (c, s) in variants.items():
        eps_fn = lambda z, t, cc, _c=c: dit.forward(params, _c, z, t, cc)
        run = jax.jit(lambda rng, cd, m: shared_sample(
            eps_fn, sched, s, rng, cd, m, null, shape))
        us, out = _time(run, key, cond, mask)
        nfe = float(out["nfe"])
        rows.append((f"sampler_e2e/{name}/K{K}N{N}T{s.total_steps}",
                     us / nfe, f"us_per_nfe total_us={us:.0f} "
                               f"nfe={nfe:.0f} {mode}"))

    for r in rows[-len(variants):]:
        print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
