"""Shared benchmark harness: builds (and caches) the three models of the
paper's Table 1 — Pre-trained, Standard FT, SAGE FT — on the procedural
corpus, plus the text/image towers used for grouping and the CLIP-proxy.

Scale knob: BENCH_FULL=1 env -> longer training / more eval prompts.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.config import OptimConfig, SageConfig, get_config
from repro.core import trainer
from repro.core.schedule import make_schedule
from repro.core.shared_sampling import independent_sample, shared_sample
from repro.data.grouped import build_grouped_dataset
from repro.data.synthetic import ShapesDataset
from repro.models import dit, text_encoder as te, vae as vae_lib

FULL = bool(int(os.environ.get("BENCH_FULL", "0")))
CACHE = pathlib.Path("experiments/bench_cache")

RES = 16                      # image resolution (latent 8x8 via /2 patch...)
N_DATA = 192 if FULL else 96
BASE_STEPS = 500 if FULL else 250
FT_STEPS = 350 if FULL else 150
TOWER_STEPS = 600 if FULL else 400
EVAL_PROMPTS = 60 if FULL else 36

SCHED = make_schedule(1000)
MODEL_CFG = get_config("sage-dit", smoke=True)          # latent 8x8x4
SAGE = SageConfig(total_steps=30, share_ratio=0.3, guidance_scale=2.0,
                  tau_min=0.6, tau_max=0.9)
OPT = OptimConfig(lr=1e-3)
TEXT_CFG = te.text_cfg(dim=MODEL_CFG.cond_dim, layers=2)
K_GROUPS, GROUP_N = 4, 3


# ---------------------------------------------------------------------------
# towers + dataset
# ---------------------------------------------------------------------------

def train_towers(init_only: bool = False):
    kp = jax.random.PRNGKey(0)
    tp = te.init_text(kp, TEXT_CFG)
    ip = te.init_image(jax.random.fold_in(kp, 1), dim=MODEL_CFG.cond_dim,
                       image=RES, layers=TEXT_CFG.n_layers)
    if init_only:
        return {"text": tp, "image": ip}
    ds = ShapesDataset(res=RES, seed=3)
    from repro.optim.optimizers import adamw, apply_updates
    opt = adamw()
    state = opt.init({"t": tp, "i": ip})

    @jax.jit
    def step(tp, ip, state, tokens, images):
        def loss(both):
            return te.contrastive_loss(both["t"], both["i"], TEXT_CFG,
                                       tokens, images)
        l, g = jax.value_and_grad(loss)({"t": tp, "i": ip})
        upd, state = opt.update(g, state, {"t": tp, "i": ip}, 1e-3)
        new = apply_updates({"t": tp, "i": ip}, upd)
        return new["t"], new["i"], state, l

    B = 32
    for i in range(TOWER_STEPS):
        imgs, prompts = ds.batch((i * B) % 2048, B)
        toks = te.tokenize(prompts, max_len=MODEL_CFG.cond_len)
        tp, ip, state, l = step(tp, ip, state, toks,
                                jnp.asarray(imgs, jnp.float32))
    return {"text": tp, "image": ip}


@functools.lru_cache(maxsize=1)
def towers():
    path = CACHE / "towers"
    if latest_step(str(path)) is not None:
        return restore_checkpoint(str(path), 0, train_towers(init_only=True))
    t = train_towers()
    save_checkpoint(str(path), 0, t)
    return t


def encode_prompts(prompts):
    t = towers()
    toks = te.tokenize(prompts, max_len=MODEL_CFG.cond_len)
    feats, pooled = te.encode_text(t["text"], TEXT_CFG, toks)
    return np.asarray(feats), np.asarray(pooled)


def quantile_taus(pooled: np.ndarray, qlo: float, qhi: float):
    """Map the paper's (tau_min, tau_max] similarity RANGE onto this text
    tower's own similarity distribution: thresholds are corpus quantiles of
    off-diagonal cosine similarity.  (The paper's absolute 0.6/0.9 values
    are CLIP-calibrated and do not transfer to a different embedding space —
    DESIGN.md §2.)"""
    from repro.core import grouping as gp
    sim = gp.similarity_matrix(pooled)
    off = sim[np.triu_indices_from(sim, 1)]
    lo = float(np.quantile(off, qlo))
    hi = float(np.quantile(off, qhi)) if qhi < 1.0 else 1.01
    return lo, max(hi, lo + 1e-4)


@functools.lru_cache(maxsize=8)
def dataset(qlo: float = 0.5, qhi: float = 1.0):
    """Grouped dataset with quantile-band similarity thresholds."""
    _, pooled = encode_prompts(tuple(
        ShapesDataset(res=RES, seed=0).sample(i)[1] for i in range(N_DATA)))
    lo, hi = quantile_taus(pooled, qlo, qhi)
    return build_grouped_dataset(
        lambda p: encode_prompts(p), n_items=N_DATA, res=RES,
        tau_min=lo, tau_max=hi, group_max=GROUP_N, seed=0)


def images_to_latents(images: np.ndarray) -> jnp.ndarray:
    """RES images -> (RES/2, RES/2, 4) latents via space-to-depth + pad.

    The paper's VAE role at benchmark scale: a fixed, invertible latent map
    (the conv VAE exists in models/vae.py and is exercised by its example;
    using a deterministic latent here keeps Table-1 runs minutes-fast and
    metric differences attributable to sampling scheme, not VAE noise)."""
    x = jnp.asarray(images, jnp.float32)
    B, H, W, C = x.shape
    x = x.reshape(B, H // 2, 2, W // 2, 2, C).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(B, H // 2, W // 2, 12)
    return x[..., :4] * 1.5

def latents_to_images(lat: jnp.ndarray) -> np.ndarray:
    """Approximate inverse of images_to_latents (first channel block)."""
    z = np.asarray(lat, np.float32) / 1.5
    B, h, w, _ = z.shape
    full = np.zeros((B, h, w, 12), np.float32)
    full[..., :4] = z
    full[..., 4:8] = z
    full[..., 8:] = z
    img = full.reshape(B, h, w, 2, 2, 3).transpose(0, 1, 3, 2, 4, 5)
    return np.clip(img.reshape(B, h * 2, w * 2, 3), -1, 1)


# ---------------------------------------------------------------------------
# the three Table-1 models
# ---------------------------------------------------------------------------

def _grouped_batches(gd, seed=0):
    while True:
        got = False
        for b in gd.iter_batches(K_GROUPS, GROUP_N, seed=seed):
            got = True
            z = images_to_latents(b["images"].reshape(-1, RES, RES, 3))
            z = z.reshape(K_GROUPS, GROUP_N, RES // 2, RES // 2, 4)
            yield {"z": z, "cond": jnp.asarray(b["cond"]),
                   "mask": jnp.asarray(b["mask"])}
        seed += 1
        if not got:
            raise RuntimeError("empty dataset")


def train_base(init_only: bool = False):
    state = trainer.init_state(MODEL_CFG, OPT, jax.random.PRNGKey(1))
    if init_only:
        return state["params"]
    gd = dataset()
    step = trainer.make_standard_train_step(MODEL_CFG, SCHED, OPT)
    it = _grouped_batches(gd)
    for i in range(BASE_STEPS):
        b = next(it)
        flat = {"z": b["z"].reshape(-1, *b["z"].shape[2:]),
                "cond": b["cond"].reshape(-1, *b["cond"].shape[2:])}
        state, m = step(state, flat, jax.random.PRNGKey(1000 + i))
    return state["params"]


@functools.lru_cache(maxsize=1)
def model_pretrained():
    path = CACHE / "base"
    if latest_step(str(path)) is not None:
        return restore_checkpoint(str(path), 0, train_base(init_only=True))
    p = train_base()
    save_checkpoint(str(path), 0, p)
    return p


def _finetune(kind: str):
    base = model_pretrained()
    state = trainer.init_state(MODEL_CFG, OPT, jax.random.PRNGKey(2),
                               base_params=base)
    gd = dataset()
    it = _grouped_batches(gd, seed=7)
    if kind == "sage":
        step = trainer.make_sage_train_step(MODEL_CFG, SAGE, SCHED, OPT)
        for i in range(FT_STEPS):
            state, m = step(state, next(it), jax.random.PRNGKey(2000 + i))
    else:
        step = trainer.make_standard_train_step(MODEL_CFG, SCHED, OPT)
        for i in range(FT_STEPS):
            b = next(it)
            flat = {"z": b["z"].reshape(-1, *b["z"].shape[2:]),
                    "cond": b["cond"].reshape(-1, *b["cond"].shape[2:])}
            state, m = step(state, flat, jax.random.PRNGKey(2000 + i))
    return state["params"]


@functools.lru_cache(maxsize=1)
def model_standard_ft():
    path = CACHE / "standard_ft"
    if latest_step(str(path)) is not None:
        return restore_checkpoint(str(path), 0, train_base(init_only=True))
    p = _finetune("standard")
    save_checkpoint(str(path), 0, p)
    return p


@functools.lru_cache(maxsize=1)
def model_sage_ft():
    path = CACHE / "sage_ft"
    if latest_step(str(path)) is not None:
        return restore_checkpoint(str(path), 0, train_base(init_only=True))
    p = _finetune("sage")
    save_checkpoint(str(path), 0, p)
    return p


MODELS = {"pretrained": model_pretrained, "standard_ft": model_standard_ft,
          "sage_ft": model_sage_ft}


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def evaluate_scheme(params, beta: float, qlo=0.5, qhi=1.0,
                    total_steps=30, seed=11, shared_uncond=False,
                    sampler="ddim"):
    """Sample EVAL_PROMPTS prompts under the given sharing ratio and compute
    FD-R / CLIP-proxy / diversity / cost saving."""
    import dataclasses as dc
    from repro.core import grouping as gp
    from repro.core import metrics

    gd = dataset(qlo, qhi)
    eval_n = min(EVAL_PROMPTS, len(gd.prompts))
    prompts = gd.prompts[:eval_n]
    cond, pooled = gd.cond[:eval_n], gd.embeds[:eval_n]
    tau_min, tau_max = quantile_taus(pooled, qlo, qhi)
    sim = gp.similarity_matrix(pooled)
    groups = gp.greedy_clique_groups(sim, tau_min, tau_max,
                                     group_max=GROUP_N)
    idx, mask = gp.pad_groups(groups, GROUP_N)
    K, N = idx.shape

    sage = dc.replace(SAGE, share_ratio=beta, total_steps=total_steps,
                      shared_uncond_cfg=shared_uncond, sampler=sampler)
    eps_fn = lambda z, t, c: dit.forward(params, MODEL_CFG, z, t, c)
    null = jnp.zeros((MODEL_CFG.cond_len, MODEL_CFG.cond_dim))
    H = MODEL_CFG.latent_size
    cond_packed = jnp.asarray(cond)[idx.reshape(-1)].reshape(
        K, N, *cond.shape[1:])

    if beta == 0.0:
        out = independent_sample(eps_fn, SCHED, sage, jax.random.PRNGKey(seed),
                                 jnp.asarray(cond), null,
                                 (H, H, MODEL_CFG.latent_channels))
        lat = out["latents"]
        gen = latents_to_images(lat)
        group_imgs = gen[idx.reshape(-1)].reshape(K, N, RES, RES, 3)
    else:
        out = shared_sample(eps_fn, SCHED, sage, jax.random.PRNGKey(seed),
                            cond_packed, jnp.asarray(mask), null,
                            (H, H, MODEL_CFG.latent_channels))
        lat = out["latents"].reshape(K * N, H, H, MODEL_CFG.latent_channels)
        gen_members = latents_to_images(lat)
        # scatter back to prompt order
        gen = np.zeros((eval_n, RES, RES, 3), np.float32)
        flat_idx = idx.reshape(-1)
        flat_mask = mask.reshape(-1) > 0
        gen[flat_idx[flat_mask]] = gen_members[flat_mask]
        group_imgs = gen_members.reshape(K, N, RES, RES, 3)

    t = towers()
    img_emb = te.encode_image(t["image"], jnp.asarray(gen),
                              dim=MODEL_CFG.cond_dim,
                              layers=TEXT_CFG.n_layers)
    real = gd.images[:eval_n]
    fd = metrics.fd_r(jnp.asarray(real), jnp.asarray(gen))
    clip_p = metrics.clip_proxy(jnp.asarray(pooled), img_emb)
    div = metrics.group_diversity(jnp.asarray(group_imgs), jnp.asarray(mask))
    cost = gp.cost_saving(groups, total_steps,
                          int(round(total_steps * (1 - beta))))
    return {"fd": fd, "clip": clip_p, "div": div,
            "cost_saving": cost["saving"], "nfe": float(out["nfe"])}
