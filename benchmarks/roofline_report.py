"""Render experiments/dryrun/*.json into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import time


def load(outdir="experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(f"{outdir}/*.json")):
        rows.append(json.load(open(f)))
    return rows


def markdown_table(rows, mesh="16x16", variant="baseline"):
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful FLOPs | model GF | mem/dev GB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r.get("variant", "baseline") != variant:
            continue
        mem = r.get("memory_analysis", {})
        dev_gb = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.2e} "
            f"| {r['memory_term_s']:.2e} | {r['collective_term_s']:.2e} "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['model_flops_global']/1e9:.0f} | {dev_gb:.1f} |")
    return "\n".join(out)


def main(rows=None):
    rows_out = rows if rows is not None else []
    data = load()
    t0 = time.time()
    for r in data:
        if r.get("variant", "baseline") != "baseline":
            continue
        rows_out.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            (time.time() - t0) * 1e6,
            f"bottleneck={r['bottleneck']};compute={r['compute_term_s']:.2e};"
            f"mem={r['memory_term_s']:.2e};coll={r['collective_term_s']:.2e}"))
        print(f"{rows_out[-1][0]},0,{rows_out[-1][2]}", flush=True)
    return rows_out


if __name__ == "__main__":
    print(markdown_table(load()))
