"""Paper Fig. 3: metrics across prompt-similarity ranges (tau_min, tau_max)
under the shared sampling scheme (beta fixed at 30%)."""
from __future__ import annotations

import time

from benchmarks import common

# quantile bands of the corpus similarity distribution (low -> high
# similarity), the tower-calibrated version of the paper's tau ranges
RANGES = [(0.05, 0.45), (0.3, 0.7), (0.5, 0.9), (0.6, 1.0)]


def main(rows=None):
    rows = rows if rows is not None else []
    for model_name in ("pretrained", "sage_ft", "standard_ft"):
        params = common.MODELS[model_name]()
        for (lo, hi) in RANGES:
            t0 = time.time()
            m = common.evaluate_scheme(params, beta=0.3, qlo=lo, qhi=hi)
            dt = (time.time() - t0) * 1e6
            rows.append((f"fig3/{model_name}/q{lo}-{hi}", dt,
                         f"fd={m['fd']:.2f};clip={m['clip']:.4f};"
                         f"div={m['div']:.4f}"))
            print(f"{rows[-1][0]},{dt:.0f},{rows[-1][2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
