"""Paper Fig. 4: metrics vs number of shared steps (of 30 total), models
trained at beta=30%.  Includes the beyond-paper shared-uncond CFG variant."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common

SHARED_STEPS = (3, 6, 9, 12, 15)


def main(rows=None):
    rows = rows if rows is not None else []
    for model_name in ("standard_ft", "sage_ft"):
        params = common.MODELS[model_name]()
        for s in SHARED_STEPS:
            beta = s / 30.0
            t0 = time.time()
            m = common.evaluate_scheme(params, beta=beta)
            dt = (time.time() - t0) * 1e6
            rows.append((f"fig4/{model_name}/shared{s}", dt,
                         f"clip={m['clip']:.4f};div={m['div']:.4f};"
                         f"save={m['cost_saving']:.3f}"))
            print(f"{rows[-1][0]},{dt:.0f},{rows[-1][2]}", flush=True)
    return rows


if __name__ == "__main__":
    main()
